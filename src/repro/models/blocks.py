"""Layer-stack wiring: pattern periods, scan-over-layers, remat policies.

The layer stack is tiled from ``cfg.block_pattern`` (e.g. recurrentgemma's
('rglru', 'rglru', 'local_attn')).  All full periods share one *stacked*
parameter pytree and run under a single ``lax.scan`` — this keeps the HLO
(and compile time) independent of depth, which is what makes the 512-device
dry-run of 40-layer models tractable and is the production idiom (MaxText).
Remainder layers (n_layers % period) get their own params and run unrolled.

Remat: the per-period body is wrapped in ``jax.checkpoint`` with a
configurable policy, so backward recompute cost/memory is a config knob
(§Perf iterates on it).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.attention import (
    AttentionConfig,
    attention,
    decode_attention,
    init_attention,
    init_kv_cache,
)
from repro.distributed.annotate import constrain
from repro.models.config import ModelConfig
from repro.models.layers import activation_fn, dense_init, init_norm, layer_norm, rms_norm
from repro.models.moe import MoEConfig, init_moe, moe_ffn
from repro.models import recurrent as rec

__all__ = ["init_stack", "stack_forward", "init_decode_state", "stack_decode"]


# ---------------------------------------------------------------------------
# sub-config builders
# ---------------------------------------------------------------------------


def _attn_cfg(cfg: ModelConfig, kind: str) -> AttentionConfig:
    return AttentionConfig(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.head_dim,
        qkv_bias=cfg.qkv_bias,
        qk_norm=cfg.qk_norm,
        rope_theta=cfg.rope_theta,
        mrope_sections=cfg.mrope_sections,
        window=cfg.window if kind == "local_attn" else None,
        blockwise_threshold=cfg.blockwise_threshold,
        chunk_q=cfg.attn_chunk_q,
        chunk_kv=cfg.attn_chunk_kv,
        unroll_blocks=not cfg.scan_layers,  # probes: exact tile accounting
    )


def _moe_cfg(cfg: ModelConfig) -> MoEConfig:
    return MoEConfig(
        d_model=cfg.d_model,
        d_ff_expert=cfg.d_ff,
        n_experts=cfg.n_experts,
        top_k=cfg.top_k,
        n_shared_experts=cfg.n_shared_experts,
        capacity_factor=cfg.capacity_factor,
        activation=cfg.activation,
        gated=cfg.gated_ffn,
    )


def _rnn_cfg(cfg: ModelConfig) -> rec.RGLRUConfig:
    return rec.RGLRUConfig(
        d_model=cfg.d_model, d_rnn=cfg.d_rnn or cfg.d_model,
        conv_width=cfg.conv_width,
    )


def _mlstm_cfg(cfg: ModelConfig) -> rec.MLSTMConfig:
    return rec.MLSTMConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, d_head=cfg.head_dim,
        chunk=cfg.mlstm_chunk, conv_width=cfg.conv_width,
    )


def _slstm_cfg(cfg: ModelConfig) -> rec.SLSTMConfig:
    return rec.SLSTMConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, d_head=cfg.head_dim
    )


def _norm_fn(cfg: ModelConfig):
    return rms_norm if cfg.norm == "rmsnorm" else layer_norm


# ---------------------------------------------------------------------------
# dense FFN
# ---------------------------------------------------------------------------


def _init_ffn(key: jax.Array, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    mult = 2 if cfg.gated_ffn else 1
    return {
        "w_in": dense_init(k1, (cfg.d_model, mult * cfg.d_ff)),
        "w_out": dense_init(k2, (cfg.d_ff, cfg.d_model)),
    }


def _ffn(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    act = activation_fn(cfg.activation)
    h = x @ params["w_in"].astype(x.dtype)
    h = constrain(h, "batch", None, "tp")
    if cfg.gated_ffn:
        g, u = jnp.split(h, 2, axis=-1)
        h = act(g) * u
    else:
        h = act(h)
    return h @ params["w_out"].astype(x.dtype)


# ---------------------------------------------------------------------------
# one layer
# ---------------------------------------------------------------------------


def _init_layer(key: jax.Array, cfg: ModelConfig, kind: str) -> dict:
    km, kf, kn = jax.random.split(key, 3)
    p: dict[str, Any] = {"norm1": init_norm(cfg.d_model)}
    if kind in ("attn", "local_attn"):
        p["mixer"] = init_attention(km, _attn_cfg(cfg, kind))
    elif kind == "rglru":
        p["mixer"] = rec.init_griffin_block(km, _rnn_cfg(cfg))
    elif kind == "mlstm":
        p["mixer"] = rec.init_mlstm(km, _mlstm_cfg(cfg))
    elif kind == "slstm":
        p["mixer"] = rec.init_slstm(km, _slstm_cfg(cfg))
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    if cfg.d_ff and kind not in ("mlstm", "slstm"):
        p["norm2"] = init_norm(cfg.d_model)
        p["ffn"] = init_moe(kf, _moe_cfg(cfg)) if cfg.n_experts else _init_ffn(kf, cfg)
    return p


def _layer_forward(
    params: dict, cfg: ModelConfig, kind: str, x: jax.Array, positions: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Returns (x', aux_loss)."""
    norm = _norm_fn(cfg)
    aux = jnp.zeros((), jnp.float32)
    x = constrain(x, "batch", None, None)
    h = norm(params["norm1"], x, cfg.norm_eps)
    if kind in ("attn", "local_attn"):
        mixed = attention(params["mixer"], _attn_cfg(cfg, kind), h, positions)
    elif kind == "rglru":
        mixed = rec.griffin_block(params["mixer"], _rnn_cfg(cfg), h)
    elif kind == "mlstm":
        mixed = rec.mlstm(params["mixer"], _mlstm_cfg(cfg), h)
    elif kind == "slstm":
        mixed = rec.slstm(params["mixer"], _slstm_cfg(cfg), h)
    else:
        raise ValueError(kind)
    x = x + mixed
    if "ffn" in params:
        h = norm(params["norm2"], x, cfg.norm_eps)
        if cfg.n_experts:
            B, T, D = h.shape
            y, aux = moe_ffn(params["ffn"], _moe_cfg(cfg), h.reshape(B, T, D))
            x = x + y
        else:
            x = x + _ffn(params["ffn"], cfg, h)
    return x, aux


# ---------------------------------------------------------------------------
# stack = scan over periods + remainder
# ---------------------------------------------------------------------------


def _remat_policy(name: str):
    if name == "nothing":
        return jax.checkpoint_policies.nothing_saveable
    if name == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    if name == "full":
        return jax.checkpoint_policies.everything_saveable
    raise ValueError(f"unknown remat policy {name!r}")


def init_stack(key: jax.Array, cfg: ModelConfig) -> dict:
    """Params: {'scanned': stacked-period pytree, 'remainder': [per-layer]}

    ``cfg.scan_layers=False`` places every layer in ``remainder`` (unrolled
    stack) — used by the roofline probes, where ``lax.scan`` bodies would be
    counted once by XLA cost analysis.
    """
    period = cfg.block_pattern
    n_full = (cfg.n_layers // len(period)) if cfg.scan_layers else 0
    n_rem = cfg.n_layers - n_full * len(period)
    keys = jax.random.split(key, n_full + 1)

    def init_period(k):
        ks = jax.random.split(k, len(period))
        return tuple(_init_layer(ks[i], cfg, kind) for i, kind in enumerate(period))

    scanned = jax.vmap(init_period)(keys[:n_full]) if n_full else None
    rem_keys = jax.random.split(keys[-1], max(n_rem, 1))
    remainder = [
        _init_layer(rem_keys[i], cfg, period[i % len(period)]) for i in range(n_rem)
    ]
    return {"scanned": scanned, "remainder": remainder}


def _period_forward(cfg: ModelConfig):
    period = cfg.block_pattern

    def fwd(carry, period_params, positions):
        x, aux = carry
        for i, kind in enumerate(period):
            x, a = _layer_forward(period_params[i], cfg, kind, x, positions)
            aux = aux + a
        return x, aux

    return fwd


def stack_forward(
    params: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Run the full layer stack. x (B, T, D) → (x', total_aux)."""
    fwd = _period_forward(cfg)
    body = jax.checkpoint(
        lambda carry, pp: (fwd(carry, pp, positions), None),
        policy=_remat_policy(cfg.remat_policy),
        prevent_cse=True,
    )
    aux0 = jnp.zeros((), jnp.float32)
    carry = (x, aux0)
    if params["scanned"] is not None:
        carry, _ = jax.lax.scan(body, carry, params["scanned"])
    for i, p in enumerate(params["remainder"]):
        kind = cfg.block_pattern[i % len(cfg.block_pattern)]
        layer = jax.checkpoint(
            lambda p_, x_, pos_, _kind=kind: _layer_forward(
                p_, cfg, _kind, x_, pos_
            ),
            policy=_remat_policy(cfg.remat_policy),
            prevent_cse=True,
        )
        x, a = layer(p, carry[0], positions)
        carry = (x, carry[1] + a)
    return carry


# ---------------------------------------------------------------------------
# decode: per-layer state threading
# ---------------------------------------------------------------------------


def _init_layer_state(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind in ("attn", "local_attn"):
        return init_kv_cache(_attn_cfg(cfg, kind), batch, max_len)
    if kind == "rglru":
        return rec.init_griffin_state(_rnn_cfg(cfg), batch)
    if kind == "mlstm":
        return rec.init_mlstm_state(_mlstm_cfg(cfg), batch)
    if kind == "slstm":
        return rec.init_slstm_state(_slstm_cfg(cfg), batch)
    raise ValueError(kind)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    period = cfg.block_pattern
    n_full = (cfg.n_layers // len(period)) if cfg.scan_layers else 0
    n_rem = cfg.n_layers - n_full * len(period)

    def one_period(_):
        return tuple(
            _init_layer_state(cfg, kind, batch, max_len) for kind in period
        )

    scanned = (
        jax.tree.map(
            lambda *leaves: jnp.stack(leaves),
            *[one_period(i) for i in range(n_full)],
        )
        if n_full
        else None
    )
    remainder = [
        _init_layer_state(cfg, period[i % len(period)], batch, max_len)
        for i in range(n_rem)
    ]
    return {"scanned": scanned, "remainder": remainder}


def _layer_decode(params, cfg: ModelConfig, kind: str, x, state, pos):
    norm = _norm_fn(cfg)
    h = norm(params["norm1"], x, cfg.norm_eps)
    if kind in ("attn", "local_attn"):
        mixed, state = decode_attention(
            params["mixer"], _attn_cfg(cfg, kind), h, state, pos
        )
    elif kind == "rglru":
        mixed, state = rec.griffin_decode(params["mixer"], _rnn_cfg(cfg), h, state)
    elif kind == "mlstm":
        mixed, state = rec.mlstm_decode(params["mixer"], _mlstm_cfg(cfg), h, state)
    elif kind == "slstm":
        mixed, state = rec.slstm_decode(params["mixer"], _slstm_cfg(cfg), h, state)
    else:
        raise ValueError(kind)
    x = x + mixed
    if "ffn" in params:
        h = norm(params["norm2"], x, cfg.norm_eps)
        if cfg.n_experts:
            y, _ = moe_ffn(params["ffn"], _moe_cfg(cfg), h)
            x = x + y
        else:
            x = x + _ffn(params["ffn"], cfg, h)
    return x, state


def stack_decode(
    params: dict, cfg: ModelConfig, state: dict, x: jax.Array, pos: jax.Array
) -> tuple[jax.Array, dict]:
    """One-token decode through the stack. x (B, 1, D)."""
    period = cfg.block_pattern

    def body(x, inputs):
        period_params, period_state = inputs
        new_states = []
        for i, kind in enumerate(period):
            x, s = _layer_decode(
                period_params[i], cfg, kind, x, period_state[i], pos
            )
            new_states.append(s)
        return x, tuple(new_states)

    new_scanned = None
    if params["scanned"] is not None:
        x, new_scanned = jax.lax.scan(
            body, x, (params["scanned"], state["scanned"])
        )
    new_rem = []
    for i, p in enumerate(params["remainder"]):
        kind = period[i % len(period)]
        x, s = _layer_decode(p, cfg, kind, x, state["remainder"][i], pos)
        new_rem.append(s)
    return x, {"scanned": new_scanned, "remainder": new_rem}
