"""Shared model layers: norms, rotary embeddings (RoPE / M-RoPE), activations.

Parameters are plain nested dicts (pytrees); sharding is attached externally
by :mod:`repro.distributed.sharding` from parameter-path rules, so the model
code stays mesh-agnostic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "layer_norm",
    "init_norm",
    "rope_frequencies",
    "apply_rope",
    "apply_mrope",
    "activation_fn",
    "dense_init",
]


def init_norm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm: fp32 *reduction*, compute-dtype normalize.

    Only the mean-of-squares runs in fp32 (one fused convert+reduce); the
    full-tensor multiplies stay in the compute dtype — the fp32 elementwise
    chain of the naive version dominates backward HBM traffic at scale
    (§Perf iteration 2: fp32 mul/add_any were the largest byte producers).
    """
    dtype = x.dtype
    var = jnp.mean(
        jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True
    )
    inv = jax.lax.rsqrt(var + eps).astype(dtype)
    return x * inv * _channel(params["scale"].astype(dtype), x.ndim)


def layer_norm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(dtype)
    return (x - mu.astype(dtype)) * inv * _channel(
        params["scale"].astype(dtype), x.ndim
    )


def _channel(v: jax.Array, ndim: int) -> jax.Array:
    """Explicitly broadcast a (C,) per-channel vector to rank ``ndim``
    (required under jax_numpy_rank_promotion='raise')."""
    return v.reshape((1,) * (ndim - 1) + (-1,))


def dense_init(key: jax.Array, shape: tuple[int, ...], scale: str = "fan_in"):
    """Truncated-normal init with 1/sqrt(fan_in) scaling (fp32 master)."""
    fan_in = shape[0] if scale == "fan_in" else shape[-1]
    std = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float = 10000.0) -> jax.Array:
    """(d_head/2,) inverse frequencies."""
    exponents = jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head
    return 1.0 / (theta**exponents)


def _rope_rotate(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """Rotate pairs (x1, x2) -> (x1 cos − x2 sin, x2 cos + x1 sin).

    x: (..., d_head) with d_head even; sin/cos broadcastable to (..., d_head/2).
    """
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 10000.0
) -> jax.Array:
    """Standard RoPE.

    Args:
      x: (B, T, H, d_head).
      positions: (B, T) int32 absolute positions.
    """
    d_head = x.shape[-1]
    inv = rope_frequencies(d_head, theta)  # (d/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv[None, None]  # (B, T, d/2)
    sin = jnp.sin(ang)[:, :, None, :]  # (B, T, 1, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    return _rope_rotate(x, sin, cos)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    sections: tuple[int, int, int],
    theta: float = 10000.0,
) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL, arXiv:2409.12191).

    The d_head/2 frequency slots are split into (temporal, height, width)
    sections; each section rotates by its own position stream.

    Args:
      x: (B, T, H, d_head).
      positions: (B, 3, T) int32 — (t, h, w) position ids per token.
      sections: frequency-slot counts per stream, summing to d_head/2.
    """
    d_head = x.shape[-1]
    assert sum(sections) == d_head // 2, (sections, d_head)
    inv = rope_frequencies(d_head, theta)  # (d/2,)
    pos = positions.astype(jnp.float32)  # (B, 3, T)
    # Build per-slot angle by selecting the stream each slot belongs to.
    stream_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=d_head // 2
    )  # (d/2,) static
    # (B, T, d/2): slot s rotates by pos[:, stream_id[s], :]
    pos_sel = jnp.einsum(
        "bst,ks->btk", pos, jax.nn.one_hot(stream_id, 3, dtype=jnp.float32)
    )
    ang = pos_sel * inv[None, None]  # (B, T, d/2)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    return _rope_rotate(x, sin, cos)


def activation_fn(name: str):
    if name == "gelu":
        return jax.nn.gelu
    if name == "silu":
        return jax.nn.silu
    if name == "relu2":  # squared ReLU (Primer / Nemotron-4)
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "relu":
        return jax.nn.relu
    raise ValueError(f"unknown activation {name!r}")
