"""Model zoo: unified decoder stack covering all 10 assigned architectures."""
from repro.models.config import ModelConfig
from repro.models.model import (
    decode_step,
    forward,
    init_params,
    init_serve_state,
    loss_fn,
    prefill,
    proxy_features,
    proxy_features_fused,
)

__all__ = [
    "ModelConfig",
    "decode_step",
    "forward",
    "init_params",
    "init_serve_state",
    "loss_fn",
    "prefill",
    "proxy_features",
    "proxy_features_fused",
]
