"""Attention: GQA/MQA/MHA with RoPE / M-RoPE, qk-norm, QKV bias, sliding
window, blockwise (flash-style) long-sequence path, and KV-cache decoding.

Three execution paths, chosen statically from (seq_len, window):

* ``dense``     — full (Tq, Tk) scores; short sequences.
* ``blockwise`` — lax.scan over KV chunks with online softmax (numerically
                  identical to dense, O(T·chunk) memory).  The TPU-native
                  equivalent of FlashAttention at the XLA level: per-chunk
                  matmuls hit the MXU, the running (m, l, acc) rescale is VPU
                  work, and no (T, T) buffer ever exists in HBM.
* ``local``     — sliding-window attention; each query chunk attends to a
                  [qc − window, qc + chunk) KV slice (Griffin/recurrentgemma).

Decoding uses a KV cache (B, S, n_kv, d_head) with in-place dynamic updates,
or a ring buffer of size `window` for local attention.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.distributed.annotate import constrain
from repro.models.layers import apply_mrope, apply_rope, dense_init, rms_norm

__all__ = ["AttentionConfig", "init_attention", "attention", "decode_attention", "init_kv_cache"]

_NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, int, int] | None = None  # Qwen2-VL
    window: int | None = None  # sliding-window size (None = global)
    blockwise_threshold: int = 8192  # switch to blockwise above this seq len
    chunk_q: int = 1024
    chunk_kv: int = 1024
    unroll_blocks: bool = False  # unroll blockwise loops (roofline probes)


def init_attention(key: jax.Array, cfg: AttentionConfig) -> dict:
    ks = jax.random.split(key, 4)
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p = {
        "wq": dense_init(ks[0], (d, H * hd)).reshape(d, H, hd),
        "wk": dense_init(ks[1], (d, KV * hd)).reshape(d, KV, hd),
        "wv": dense_init(ks[2], (d, KV * hd)).reshape(d, KV, hd),
        "wo": dense_init(ks[3], (H * hd, d)).reshape(H, hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), jnp.float32)
        p["bk"] = jnp.zeros((KV, hd), jnp.float32)
        p["bv"] = jnp.zeros((KV, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((hd,), jnp.float32)}
    return p


def _project_qkv(params, cfg: AttentionConfig, x, positions):
    """x (B, T, D) → q (B, T, H, hd), k/v (B, T, KV, hd), rope applied."""
    dtype = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dtype))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(dtype))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(dtype))
    q = constrain(q, "batch", None, "tp", None)
    # KV heads: shard over model ONLY when exactly divisible; otherwise
    # replicate (they are small) so the GQA repeat below is a local slice —
    # uneven kv sharding through broadcast+reshape degenerates to an
    # all-gather of the full repeated KV (§Perf iteration 1: ~1 GB/layer).
    k = constrain(k, "batch", None, "tp", None, strict=True)
    v = constrain(v, "batch", None, "tp", None, strict=True)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    if cfg.qk_norm:
        q = rms_norm(params["q_norm"], q)
        k = rms_norm(params["k_norm"], k)
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, T, KV, hd) → (B, T, KV·n_rep, hd) by head-group broadcast."""
    if n_rep == 1:
        return k
    b, t, kv, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, t, kv, n_rep, hd))
    return k.reshape(b, t, kv * n_rep, hd)


def _dense_attention(q, k, v, scale, causal_offset, window):
    """q (B,Tq,H,hd), k/v (B,Tk,H,hd). Causal: query i attends keys ≤ i+off."""
    B, Tq, H, hd = q.shape
    Tk = k.shape[1]
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) * scale
    qi = jnp.arange(Tq)[:, None] + causal_offset
    ki = jnp.arange(Tk)[None, :]
    mask = ki <= qi
    if window is not None:
        mask &= ki > qi - window
    scores = jnp.where(mask[None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshk->bqhk", probs, v)


def _blockwise_attention(q, k, v, scale, cfg: AttentionConfig):
    """Online-softmax scan over KV chunks (flash-style, exact).

    Causal, optional sliding window. Chunks are static so XLA sees a small
    steady-state program; memory is O(B·H·Tq·hd) + one (cq, ckv) score tile.
    """
    import math

    B, Tq, H, hd = q.shape
    Tk = k.shape[1]
    # Clamp chunk sizes to divisors of the sequence lengths.
    cq = math.gcd(min(cfg.chunk_q, Tq), Tq)
    ckv = math.gcd(min(cfg.chunk_kv, Tk), Tk)
    nq, nk = Tq // cq, Tk // ckv
    q = q.reshape(B, nq, cq, H, hd)

    def q_block(qi, qc):
        """Attend one query chunk to all (visible) KV chunks."""
        m0 = jnp.full((B, H, cq, 1), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, cq, 1), jnp.float32)
        acc0 = jnp.zeros((B, H, cq, hd), jnp.float32)

        def kv_body(carry, kj):
            m, l, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(k, kj * ckv, ckv, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, kj * ckv, ckv, axis=1)
            s = jnp.einsum("bqhk,bshk->bhqs", qc, ks).astype(jnp.float32) * scale
            qpos = qi * cq + jnp.arange(cq)[:, None]
            kpos = kj * ckv + jnp.arange(ckv)[None, :]
            mask = kpos <= qpos
            if cfg.window is not None:
                mask &= kpos > qpos - cfg.window
            s = jnp.where(mask[None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            # Explicitly zero masked probs: if every key in the chunk is
            # masked m_new stays −inf and exp(s − m_new) would be 1.
            p = jnp.exp(s - m_new) * mask[None, None]
            corr = jnp.exp(jnp.minimum(m - m_new, 0.0))
            l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
            pv = jnp.einsum("bhqs,bshk->bhqk", p.astype(q.dtype), vs)
            acc_new = acc * corr + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        def kv_step(carry, kj):
            # Skip chunks that are entirely invisible to this query chunk
            # (strictly-future chunks; for windowed attention also chunks
            # strictly older than the window) without paying their FLOPs.
            # Position units, not chunk units: cq and ckv may differ.
            visible = kj * ckv <= (qi + 1) * cq - 1
            if cfg.window is not None:
                visible &= (kj + 1) * ckv > qi * cq - cfg.window
            return jax.lax.cond(
                visible, kv_body, lambda c, _: (c, None), carry, kj
            )

        if cfg.unroll_blocks:
            # probes: unrolled, statically-skipped tiles → exact cost analysis
            carry = (m0, l0, acc0)
            qi_c = int(qi)
            for kj in range(nk):
                lo_vis = kj * ckv <= (qi_c + 1) * cq - 1
                if cfg.window is not None:
                    lo_vis = lo_vis and (kj + 1) * ckv > qi_c * cq - cfg.window
                if lo_vis:
                    carry, _ = kv_body(carry, jnp.int32(kj))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, acc0), jnp.arange(nk)
            )
        out = acc / jnp.maximum(l, 1e-30)
        return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, cq, H, hd)

    if cfg.unroll_blocks:
        outs = jnp.stack([q_block(qi, q[:, qi]) for qi in range(nq)])
    else:
        outs = jax.lax.map(lambda qi: q_block(qi, q[:, qi]), jnp.arange(nq))
    # outs: (nq, B, cq, H, hd) → (B, Tq, H, hd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, Tq, H, hd)


def attention(
    params: dict,
    cfg: AttentionConfig,
    x: jax.Array,
    positions: jax.Array,
) -> jax.Array:
    """Full self-attention over x (B, T, D) → (B, T, D). Causal."""
    B, T, D = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    k = constrain(_repeat_kv(k, n_rep), "batch", None, "tp", None)
    v = constrain(_repeat_kv(v, n_rep), "batch", None, "tp", None)
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))
    if T > cfg.blockwise_threshold or (
        cfg.window is not None and T > 2 * cfg.window
    ):
        out = _blockwise_attention(q, k, v, scale, cfg)
    else:
        out = _dense_attention(q, k, v, scale, 0, cfg.window)
    return jnp.einsum("bthk,hkd->btd", out, params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Decoding with KV cache
# ---------------------------------------------------------------------------


def init_kv_cache(
    cfg: AttentionConfig, batch: int, max_len: int, dtype=jnp.bfloat16
) -> dict:
    """KV cache; ring buffer of size window for local attention."""
    size = max_len if cfg.window is None else min(cfg.window, max_len)
    shape = (batch, size, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def decode_attention(
    params: dict,
    cfg: AttentionConfig,
    x: jax.Array,
    cache: dict,
    pos: jax.Array,
) -> tuple[jax.Array, dict]:
    """One-token decode. x (B, 1, D); pos () or (B,) current position.

    Returns (out (B, 1, D), new_cache). The cache write is donate-friendly
    (pure functional update via dynamic_update_slice).
    """
    B = x.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,)).astype(jnp.int32)
    if cfg.mrope_sections is not None:
        # text-only decode: all three M-RoPE streams advance together
        positions = jnp.broadcast_to(pos_b[:, None, None], (B, 3, 1))
    else:
        positions = pos_b[:, None]  # (B, 1)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)

    size = cache["k"].shape[1]
    slot = (pos_b[0] % size).astype(jnp.int32)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)

    # Grouped-query attention WITHOUT materializing repeated KV: the cache
    # stays (B, S, KV, hd) with hd sharded over `model` (serve_state_specs),
    # so the per-step cache update is local and the only collective is a
    # small partial-sum all-reduce of the (B, KV, G, S) scores.
    n_rep = cfg.n_heads // cfg.n_kv_heads
    B_, KV, hd = q.shape[0], cfg.n_kv_heads, cfg.d_head
    q5 = q[:, 0].reshape(B_, KV, n_rep, hd)  # (B, KV, G, hd)
    q5 = constrain(q5, "batch", None, None, "tp", strict=True)
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))
    k = k_cache.astype(q.dtype)
    v = v_cache.astype(q.dtype)
    scores = jnp.einsum("bkgh,bskh->bkgs", q5, k).astype(jnp.float32) * scale
    # Valid slots: ring semantics — slot index s holds absolute position
    #   p(s) = s            if s <= pos (first wrap not reached), else
    #   p(s) = s + size·k   — validity reduces to: filled and within window.
    s_idx = jnp.arange(size)[None, :]  # (1, size)
    cur = pos_b[:, None]
    if cfg.window is None:
        valid = s_idx <= cur  # cache size == max_len, no wrap
    else:
        # Ring of size w: slot s holds absolute position
        # p(s) = cur − ((cur − s) mod w) ∈ (cur − w, cur]; valid iff written.
        abs_pos = cur - jnp.mod(cur - s_idx, size)
        valid = abs_pos >= 0
    scores = jnp.where(valid[:, None, None, :], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v)  # (B, KV, G, hd)
    out = out.reshape(B_, 1, KV * n_rep, hd)
    out = jnp.einsum("bthk,hkd->btd", out, params["wo"].astype(x.dtype))
    return out, {"k": k_cache, "v": v_cache}
