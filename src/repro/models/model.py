"""LMModel: frontends (tokens / stub embeddings), backbone stack, heads,
chunked weighted cross-entropy (CRAIG γ weights), prefill & decode.

Public entry points (all pure functions over a params pytree):

* ``init_params(key, cfg)``                   — fp32 master weights.
* ``forward(params, cfg, batch)``             — hidden states (B, T, D).
* ``loss_fn(params, cfg, batch)``             — (loss, metrics); per-example
  weights ``batch['weights']`` implement the paper's per-element stepsizes.
* ``prefill(params, cfg, batch, max_len)``    — hidden + initialized caches.
* ``decode_step(params, cfg, state, batch)``  — one-token serve step.
* ``proxy_features(params, cfg, batch)``      — CRAIG pooled unembed-input
  gradient proxies (forward pass + fused CE-backward head).

Batch dict keys (ShapeDtypeStruct-compatible, see launch/dryrun.py):
  tokens      (B, T) int32            [frontend == 'tokens']
  embeddings  (B, T, D) bf16          [frontend == 'embeddings' — stub]
  labels      (B, T) or (B, T, n_codebooks) int32
  positions   (B, T) or (B, 3, T) int32 (M-RoPE)
  weights     (B,) fp32 — CRAIG γ (defaults to 1)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.blocks import (
    init_decode_state,
    init_stack,
    stack_decode,
    stack_forward,
)
from repro.distributed.annotate import constrain
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, init_norm, layer_norm, rms_norm

__all__ = [
    "init_params",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
    "proxy_features",
    "proxy_features_fused",
    "init_serve_state",
]

COMPUTE_DTYPE = jnp.bfloat16


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    ke, ks, kh = jax.random.split(key, 3)
    p: dict[str, Any] = {"stack": init_stack(ks, cfg)}
    if cfg.frontend == "tokens":
        # std 1/sqrt(d_model), the usual lookup-table scale; vocab padded to
        # a lane/shard multiple (padded logit columns are masked in the loss)
        p["embed"] = dense_init(ke, (cfg.padded_vocab, cfg.d_model), "fan_out")
    p["final_norm"] = init_norm(cfg.d_model)
    if cfg.n_codebooks > 1:
        p["unembed"] = jax.vmap(
            lambda k: dense_init(k, (cfg.d_model, cfg.padded_vocab))
        )(jax.random.split(kh, cfg.n_codebooks))
    elif cfg.tie_embeddings and cfg.frontend == "tokens":
        pass  # reuse embed
    else:
        p["unembed"] = dense_init(kh, (cfg.d_model, cfg.padded_vocab))
    return p


def _norm(cfg: ModelConfig):
    return rms_norm if cfg.norm == "rmsnorm" else layer_norm


def _unembed_matrix(params: dict, cfg: ModelConfig) -> jax.Array:
    if "unembed" in params:
        return params["unembed"]
    return params["embed"].T  # tied


def _embed_input(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    if cfg.frontend == "tokens":
        x = params["embed"][batch["tokens"]]
    else:
        # modality frontend is a stub: precomputed frame/patch embeddings
        x = batch["embeddings"]
    return constrain(x.astype(COMPUTE_DTYPE), "batch", None, None)


def _positions(cfg: ModelConfig, batch: dict) -> jax.Array:
    if "positions" in batch:
        return batch["positions"]
    ref = batch["tokens"] if cfg.frontend == "tokens" else batch["embeddings"]
    B, T = ref.shape[0], ref.shape[1]
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[:, None], (B, 3, T))
    return pos


def forward(params: dict, cfg: ModelConfig, batch: dict) -> tuple[jax.Array, jax.Array]:
    """Returns (hidden (B, T, D) post-final-norm, aux_loss)."""
    x = _embed_input(params, cfg, batch)
    positions = _positions(cfg, batch)
    x, aux = stack_forward(params["stack"], cfg, x, positions)
    x = _norm(cfg)(params["final_norm"], x, cfg.norm_eps)
    return x, aux


# ---------------------------------------------------------------------------
# loss: chunked weighted CE
# ---------------------------------------------------------------------------


def _chunked_ce(
    hidden: jax.Array,
    unembed: jax.Array,
    labels: jax.Array,
    chunk: int,
    valid_v: int | None = None,
) -> jax.Array:
    """Per-token CE, scanning over sequence chunks with remat.

    hidden (B, T, D), unembed (D, V), labels (B, T) → (B, T) fp32 losses.
    The (B, chunk, V) logits are transient per scan step (remat in bwd), so
    peak memory is independent of T — required at vocab 152k–256k.
    """
    B, T, D = hidden.shape
    V = unembed.shape[1]
    n_chunks = max(T // chunk, 1)
    if T % chunk != 0:
        n_chunks, chunk = 1, T
    h = jnp.moveaxis(hidden.reshape(B, n_chunks, chunk, D), 1, 0)
    y = jnp.moveaxis(labels.reshape(B, n_chunks, chunk), 1, 0)

    pad_mask = None
    if valid_v is not None and valid_v < V:
        pad_mask = jnp.where(jnp.arange(V) < valid_v, 0.0, -1e30)

    @jax.checkpoint
    def one(h_c, y_c):
        logits = (h_c.astype(COMPUTE_DTYPE) @ unembed.astype(COMPUTE_DTYPE)).astype(
            jnp.float32
        )
        logits = constrain(logits, "batch", None, "tp")
        if pad_mask is not None:
            logits = logits + pad_mask[None, None]
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via one-hot reduce — NOT take_along_axis: a gather along
        # the model-sharded vocab dim forces SPMD to replicate full logits.
        onehot = jax.nn.one_hot(y_c, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.sum(logits * onehot, axis=-1)
        return lse - gold

    losses = jax.lax.map(lambda xs: one(*xs), (h, y))  # (n_chunks, B, chunk)
    return jnp.moveaxis(losses, 0, 1).reshape(B, T)


def loss_fn(
    params: dict, cfg: ModelConfig, batch: dict
) -> tuple[jax.Array, dict]:
    """Weighted mean CE. CRAIG γ weights enter as per-example loss weights —
    exactly the per-element stepsizes of paper Eq. 20 under linear loss
    scaling (DESIGN.md §7.3)."""
    hidden, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    B = hidden.shape[0]
    w = batch.get("weights")
    if w is None:
        w = jnp.ones((B,), jnp.float32)

    unembed = _unembed_matrix(params, cfg)
    if cfg.n_codebooks > 1:
        per_tok = 0.0
        for c in range(cfg.n_codebooks):
            per_tok = per_tok + _chunked_ce(
                hidden, unembed[c], labels[..., c], cfg.logit_chunk,
                valid_v=cfg.vocab_size,
            )
        per_tok = per_tok / cfg.n_codebooks
    else:
        per_tok = _chunked_ce(
            hidden, unembed, labels, cfg.logit_chunk, valid_v=cfg.vocab_size
        )

    per_example = jnp.mean(per_tok, axis=-1)  # (B,)
    denom = jnp.maximum(jnp.sum(w), 1e-6)
    loss = jnp.sum(per_example * w) / denom
    total = loss + 1e-2 * aux
    metrics = {
        "loss": loss,
        "aux_loss": aux,
        "per_example_loss": per_example,
    }
    return total, metrics


# ---------------------------------------------------------------------------
# CRAIG proxy extraction (selection forward pass)
# ---------------------------------------------------------------------------


def proxy_features(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Pooled unembed-input gradient proxies (B, D) — see core/proxy.py."""
    from repro.core.proxy import lm_unembed_input_proxy

    hidden, _ = forward(params, cfg, batch)
    unembed = _unembed_matrix(params, cfg)
    labels = batch["labels"]
    if cfg.n_codebooks > 1:
        feats = 0.0
        for c in range(cfg.n_codebooks):
            feats = feats + lm_unembed_input_proxy(
                hidden, unembed[c], labels[..., c], chunk=cfg.logit_chunk,
                valid_v=cfg.vocab_size, compute_dtype=COMPUTE_DTYPE,
            )
        return feats / cfg.n_codebooks
    return lm_unembed_input_proxy(
        hidden, unembed, labels, chunk=cfg.logit_chunk,
        valid_v=cfg.vocab_size, compute_dtype=COMPUTE_DTYPE,
    )


def proxy_features_fused(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
    *,
    compute_dtype=COMPUTE_DTYPE,
    interpret: bool | None = None,
) -> jax.Array:
    """Pooled unembed-input proxies via the fused ``ce_proxy`` Pallas kernel.

    Same contract as :func:`proxy_features` — (B, D) fp32, mean over tokens
    — but the CE-backward head runs the flash-style vocab-blocked kernel
    (kernels/ce_proxy.py) instead of the chunked einsum scan: one pass over
    W per token block, softmax never resident at (T, V).  The two paths
    agree on vocab-padded configs (the kernel's ``valid_v`` bias mirrors
    ``lm_unembed_input_proxy``'s; tests/test_proxy.py gates parity).  All
    sequences share one token stream: per-token gradients are independent,
    so (B, T) flattens to (B·T,) for the kernel and pools back per sequence.
    """
    from repro.kernels import ops

    hidden, _ = forward(params, cfg, batch)
    unembed = _unembed_matrix(params, cfg)
    labels = batch["labels"]
    B, T, D = hidden.shape
    flat_h = hidden.reshape(B * T, D)

    def one(w, y):
        g = ops.ce_proxy(
            flat_h, w, y.reshape(B * T), valid_v=cfg.vocab_size,
            compute_dtype=compute_dtype, interpret=interpret,
        )
        return jnp.mean(g.reshape(B, T, D), axis=1)

    if cfg.n_codebooks > 1:
        feats = 0.0
        for c in range(cfg.n_codebooks):
            feats = feats + one(unembed[c], labels[..., c])
        return feats / cfg.n_codebooks
    return one(unembed, labels)


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def init_serve_state(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Decode caches/states for all layers + current position counter."""
    return {
        "layers": init_decode_state(cfg, batch, max_len),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(
    params: dict, cfg: ModelConfig, batch: dict
) -> tuple[jax.Array, jax.Array]:
    """Prefill forward: full-sequence hidden states + last-token logits.

    (Cache materialization during prefill is the decode path's job in this
    framework; the prefill dry-run cell measures the forward cost, which
    dominates.)  Returns (hidden (B,T,D), last_logits (B, V)).
    """
    hidden, _ = forward(params, cfg, batch)
    unembed = _unembed_matrix(params, cfg)
    last = hidden[:, -1].astype(COMPUTE_DTYPE)
    if cfg.n_codebooks > 1:
        logits = jnp.einsum(
            "bd,cdv->bcv", last, unembed.astype(COMPUTE_DTYPE)
        ).astype(jnp.float32)
    else:
        logits = (last @ unembed.astype(COMPUTE_DTYPE)).astype(jnp.float32)
    return hidden, logits


def decode_step(
    params: dict, cfg: ModelConfig, state: dict, batch: dict
) -> tuple[jax.Array, dict]:
    """One-token decode. batch: {'tokens': (B, 1)} or {'embeddings': (B,1,D)}.

    Returns (logits (B, V) [or (B, C, V)], new_state). Cache/state tensors
    are functionally updated and donate-friendly.
    """
    if cfg.frontend == "tokens":
        x = params["embed"][batch["tokens"]].astype(COMPUTE_DTYPE)
    else:
        x = batch["embeddings"].astype(COMPUTE_DTYPE)
    pos = state["pos"]
    x, new_layers = stack_decode(params["stack"], cfg, state["layers"], x, pos)
    x = _norm(cfg)(params["final_norm"], x, cfg.norm_eps)
    unembed = _unembed_matrix(params, cfg)
    last = x[:, 0].astype(COMPUTE_DTYPE)
    if cfg.n_codebooks > 1:
        logits = jnp.einsum(
            "bd,cdv->bcv", last, unembed.astype(COMPUTE_DTYPE)
        ).astype(jnp.float32)
    else:
        logits = (last @ unembed.astype(COMPUTE_DTYPE)).astype(jnp.float32)
    new_state = {"layers": new_layers, "pos": pos + 1}
    return logits, new_state
