"""Mixture-of-Experts FFN with top-k routing and expert parallelism.

Dispatch is *gather-based* (index buffers), not one-hot-einsum based: the
(tokens, experts, capacity) combine tensor of GShard-style dispatch is
O(S·E·C) and dwarfs activations at dbrx/moonshot scale.  Instead we

  1. route: top-k expert ids + renormalized gate weights per token,
  2. bucket: position-in-expert via a cumsum over the one-hot assignment
     (small (S·k, E) int tensor), drop tokens beyond capacity,
  3. scatter token ids into an (E, C) index buffer,
  4. gather tokens → (G, E, C, D) under vmap (per-group gathers keep SPMD
     locality), expert dim pinned to `model` (expert parallelism — the
     reshard IS the all-to-all),
  5. per-expert FFN via batched einsum directly on (G, E, C, D) — merging
     the sharded G dim in a reshape degenerates to full rematerialization
     (EXPERIMENTS.md §Perf iteration 2),
  6. gather outputs back per group, gate-weight, and SUM the K contiguous
     copies per token (scatter-free combine).

Aux load-balancing loss (Switch §2.2) is returned for the trainer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.annotate import constrain
from repro.models.layers import activation_fn, dense_init

__all__ = ["MoEConfig", "init_moe", "moe_ffn"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff_expert: int
    n_experts: int
    top_k: int
    n_shared_experts: int = 0  # DeepSeek/Moonlight-style always-on experts
    capacity_factor: float = 1.25
    activation: str = "silu"
    gated: bool = True  # SwiGLU-style experts


def init_moe(key: jax.Array, cfg: MoEConfig) -> dict:
    ks = jax.random.split(key, 5)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    p = {
        "router": dense_init(ks[0], (d, E)),
        "experts_in": jax.vmap(
            lambda k: dense_init(k, (d, (2 if cfg.gated else 1) * f))
        )(jax.random.split(ks[1], E)),  # (E, d, 2f)
        "experts_out": jax.vmap(lambda k: dense_init(k, (f, d)))(
            jax.random.split(ks[2], E)
        ),  # (E, f, d)
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared_in"] = dense_init(ks[3], (d, (2 if cfg.gated else 1) * fs))
        p["shared_out"] = dense_init(ks[4], (fs, d))
    return p


def _expert_ffn(cfg: MoEConfig, w_in, w_out, x):
    """x (..., E, C, D) → (..., E, C, D) batched per-expert FFN."""
    act = activation_fn(cfg.activation)
    h = jnp.einsum("...ecd,edf->...ecf", x, w_in.astype(x.dtype))
    if cfg.gated:
        g, u = jnp.split(h, 2, axis=-1)
        h = act(g) * u
    else:
        h = act(h)
    return jnp.einsum("...ecf,efd->...ecd", h, w_out.astype(x.dtype))


def moe_ffn(
    params: dict, cfg: MoEConfig, x: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """MoE FFN over x (G, S, D) token groups.

    Returns (y (G, S, D), aux_loss ()). Groups are dispatch domains: capacity
    C = ceil(S·k/E)·capacity_factor per group; each group's dispatch indices
    are local, so with G sharded over (pod, data) and experts over `model`,
    cross-device traffic is exactly the expert all-to-all.
    """
    G, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = int(S * K / E * cfg.capacity_factor + 0.5)
    C = max(8, ((C + 7) // 8) * 8)  # pad to 8 for TPU-friendly layout
    dtype = x.dtype

    logits = jnp.einsum("gsd,de->gse", x, params["router"].astype(dtype))
    logits32 = logits.astype(jnp.float32)
    gates, eidx = jax.lax.top_k(logits32, K)  # (G, S, K)
    gates = jax.nn.softmax(gates, axis=-1)  # renormalize over the top-k

    # Aux load-balance loss (Switch): E · Σ_e frac_tokens_e · frac_router_e
    probs = jax.nn.softmax(logits32, axis=-1)  # (G, S, E)
    me = jnp.mean(probs, axis=(0, 1))
    onehot_top1 = jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(onehot_top1, axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # Routing bookkeeping per group (small int tensors; vmap is fine).
    def positions_one(eidx_g):
        flat_e = eidx_g.reshape(-1)  # (S·K,)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (S·K, E)
        p = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot, axis=1)
        kp = p < C
        tok = jnp.repeat(jnp.arange(S), K)
        b = jnp.zeros((E, C), jnp.int32)
        b = b.at[jnp.where(kp, flat_e, 0), jnp.where(kp, p, 0)].add(
            jnp.where(kp, tok + 1, 0), mode="drop"
        )
        return b, p, kp

    buf, pos, keep = jax.vmap(positions_one)(eidx)
    # buf (G, E, C); pos/keep (G, S·K)

    # Dispatch: per-group gather under vmap — the gather indices are
    # group-local, and the mapped dim keeps SPMD locality (a flattened
    # global gather forces x to replicate: measured 27x collective blow-up).
    def gather_one(xg, bufg):
        g = xg[jnp.maximum(bufg - 1, 0)]  # (E, C, D)
        return jnp.where((bufg > 0)[..., None], g, 0)

    gathered = jax.vmap(gather_one)(x, buf)  # (G, E, C, D) bf16
    # Pin expert parallelism HERE: group dim over batch, experts over model.
    # The group-local → expert-sharded reshard is the all-to-all.  Never
    # reshape (G·C) — merging a sharded dim degenerates to full remat.
    gathered = constrain(gathered, "batch", "tp", None, None)

    # Expert compute directly on (G, E, C, D) — no sharded-dim reshapes.
    ex_out = _expert_ffn(
        cfg, params["experts_in"], params["experts_out"], gathered
    )
    ex_out = constrain(ex_out, "batch", "tp", None, None)

    # Combine: per-group gather back + gate-weight + sum the K copies per
    # token (copies are contiguous — no scatter).
    def combine_one(ex_g, flat_e_g, pos_g, keep_g, gates_g):
        vals = ex_g[flat_e_g, jnp.where(keep_g, pos_g, 0)]  # (S·K, D)
        vals = jnp.where(keep_g[:, None], vals, 0).astype(dtype)
        w = gates_g.reshape(S * K, 1).astype(dtype)
        return jnp.sum((vals * w).reshape(S, K, D), axis=1)

    y = jax.vmap(combine_one)(
        ex_out, eidx.reshape(G, S * K), pos, keep, gates
    )

    if cfg.n_shared_experts:
        act = activation_fn(cfg.activation)
        h = x @ params["shared_in"].astype(dtype)
        if cfg.gated:
            g, u = jnp.split(h, 2, axis=-1)
            h = act(g) * u
        else:
            h = act(h)
        y = y + h @ params["shared_out"].astype(dtype)
    return y, aux
