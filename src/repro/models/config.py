"""Unified model configuration covering the 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description (one instance per configs/<arch>.py).

    ``block_pattern`` is the repeating unit of layer kinds; the layer stack is
    pattern tiled to ``n_layers`` (remainder layers get their own params —
    see blocks.py).  Kinds: 'attn' (global), 'local_attn' (sliding window),
    'mlstm', 'slstm', 'rglru'.  Every layer kind is followed by an FFN unless
    ``d_ff == 0`` (xLSTM: projections live inside the cell).
    """

    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 → d_model // n_heads
    block_pattern: tuple[str, ...] = ("attn",)
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: int | None = None  # for 'local_attn' layers
    mrope_sections: tuple[int, int, int] | None = None
    # ffn
    activation: str = "silu"
    gated_ffn: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # recurrent
    d_rnn: int = 0  # rg-lru width (0 → d_model)
    conv_width: int = 4
    mlstm_chunk: int = 256
    # frontend / heads
    frontend: Literal["tokens", "embeddings"] = "tokens"
    n_codebooks: int = 1  # musicgen: parallel output heads
    tie_embeddings: bool = False
    # norm
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-6
    # training-time details
    scan_layers: bool = True  # False → unrolled stack (roofline probes)
    remat_policy: str = "nothing"  # nothing | dots | full
    blockwise_threshold: int = 8192
    attn_chunk_q: int = 1024
    attn_chunk_kv: int = 1024
    logit_chunk: int = 1024  # chunked CE vocab-matmul chunk (sequence dim)
    # citation provenance
    source: str = ""

    vocab_pad_multiple: int = 128  # pad vocab for clean model-axis sharding

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        reps = -(-self.n_layers // len(self.block_pattern))  # ceil
        return (self.block_pattern * reps)[: self.n_layers]

    @property
    def is_subquadratic(self) -> bool:
        """True if no layer is global full attention (long_500k eligible)."""
        return all(k != "attn" for k in self.layer_kinds)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + heads)."""
        d, hd = self.d_model, self.head_dim
        total = 0
        if self.frontend == "tokens":
            total += self.vocab_size * d
        total += self.n_codebooks * d * self.vocab_size  # unembed head(s)
        for kind in self.layer_kinds:
            if kind in ("attn", "local_attn"):
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads)
                total += self.n_heads * hd * d
                if self.qkv_bias:
                    total += hd * (self.n_heads + 2 * self.n_kv_heads)
                if self.qk_norm:
                    total += 2 * hd
            elif kind == "rglru":
                r = self.d_rnn or d
                total += 3 * d * r + 2 * r * r + self.conv_width * r
            elif kind == "mlstm":
                di = self.n_heads * hd
                total += d * 2 * di + di * d + 3 * di * di + di * 2 * self.n_heads
            elif kind == "slstm":
                di = self.n_heads * hd
                total += d * 4 * di + 4 * self.n_heads * hd * hd + di * d
            if self.d_ff and kind not in ("mlstm", "slstm"):
                if self.n_experts:
                    total += d * self.n_experts  # router
                    per = d * (2 if self.gated_ffn else 1) * self.d_ff + self.d_ff * d
                    total += self.n_experts * per
                    total += self.n_shared_experts * per
                else:
                    total += d * (2 if self.gated_ffn else 1) * self.d_ff
                    total += self.d_ff * d
            total += 2 * d  # the two pre-norms
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        per = d * (2 if self.gated_ffn else 1) * self.d_ff + self.d_ff * d
        inactive = (self.n_experts - self.top_k) * per * sum(
            1 for k in self.layer_kinds
        )
        return self.param_count() - inactive
