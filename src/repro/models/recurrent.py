"""Recurrent sequence mixers: RG-LRU (Griffin/recurrentgemma), mLSTM & sLSTM
(xLSTM).  All three provide a parallel training/prefill form and an O(1)
per-token decode form with an explicit state pytree, so the same weights
serve `train_step`, `prefill_step`, and `serve_step` (incl. long_500k).

TPU adaptation notes (DESIGN.md §2):
  * RG-LRU uses a log-space associative scan (`lax.associative_scan`) —
    log-depth on the sequence axis instead of the GPU kernel's sequential
    CUDA scan.
  * mLSTM uses the chunkwise-parallel form (intra-chunk quadratic attention
    on the MXU + inter-chunk recurrent state carry), the standard way linear
    recurrences are mapped onto systolic hardware.
  * sLSTM is inherently sequential (memory mixing breaks associativity);
    it runs as a `lax.scan` over time with all four gates fused per step.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

__all__ = [
    "RGLRUConfig",
    "init_griffin_block",
    "griffin_block",
    "griffin_decode",
    "init_griffin_state",
    "MLSTMConfig",
    "init_mlstm",
    "mlstm",
    "mlstm_decode",
    "init_mlstm_state",
    "SLSTMConfig",
    "init_slstm",
    "slstm",
    "slstm_decode",
    "init_slstm_state",
]

_C_RGLRU = 8.0  # Griffin's fixed recurrence sharpness constant


# ===========================================================================
# RG-LRU + temporal conv (Griffin recurrent block)
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    d_rnn: int  # recurrence width
    conv_width: int = 4


def init_griffin_block(key: jax.Array, cfg: RGLRUConfig) -> dict:
    ks = jax.random.split(key, 7)
    d, r = cfg.d_model, cfg.d_rnn
    # Λ init so that a = sigmoid(Λ)^c is in [0.9, 0.999] (Griffin §2.4)
    u = jax.random.uniform(ks[0], (r,), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(u ** (1.0 / _C_RGLRU) / (1 - u ** (1.0 / _C_RGLRU)))
    return {
        "w_x": dense_init(ks[1], (d, r)),  # input branch
        "w_gate": dense_init(ks[2], (d, r)),  # gelu gate branch
        "w_out": dense_init(ks[3], (r, d)),
        "conv": dense_init(ks[4], (cfg.conv_width, r)) * 0.1,
        "w_a": dense_init(ks[5], (r, r)),  # recurrence gate
        "w_i": dense_init(ks[6], (r, r)),  # input gate
        "lam": lam,
        "b_a": jnp.zeros((r,), jnp.float32),
        "b_i": jnp.zeros((r,), jnp.float32),
    }


def _rglru_scan(params, u: jax.Array) -> jax.Array:
    """RG-LRU over u (B, T, R) via log-space associative scan.

    r_t = σ(u W_a + b_a); i_t = σ(u W_i + b_i)
    a_t = exp(c · r_t · log σ(Λ))          (∈ (0,1))
    h_t = a_t h_{t−1} + sqrt(1 − a_t²) · (i_t ⊙ u_t)
    """
    dtype = u.dtype
    u32 = u.astype(jnp.float32)
    r_g = jax.nn.sigmoid(u32 @ params["w_a"] + params["b_a"][None, None])
    i_g = jax.nn.sigmoid(u32 @ params["w_i"] + params["b_i"][None, None])
    log_a = (
        _C_RGLRU * r_g * jax.nn.log_sigmoid(params["lam"])[None, None]
    )  # (B,T,R) ≤ 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i_g * u32)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(dtype)


def _causal_conv(w: jax.Array, x: jax.Array) -> jax.Array:
    """Depthwise causal temporal conv, width K: y_t = Σ_k w_k x_{t−K+1+k}."""
    K = w.shape[0]
    pads = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for k in range(K):  # K is tiny (4); unrolled adds, no conv op needed
        out = out + pads[:, k : k + x.shape[1], :] * w[k][None, None]
    return out


def griffin_block(params: dict, cfg: RGLRUConfig, x: jax.Array) -> jax.Array:
    """Griffin recurrent block: gate ⊙ RG-LRU(conv(proj(x))) → out proj."""
    dtype = x.dtype
    gate = jax.nn.gelu(x @ params["w_gate"].astype(dtype))
    u = x @ params["w_x"].astype(dtype)
    u = _causal_conv(params["conv"].astype(dtype), u)
    h = _rglru_scan(params, u)
    return (gate * h) @ params["w_out"].astype(dtype)


def init_griffin_state(cfg: RGLRUConfig, batch: int, dtype=jnp.float32) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_rnn), dtype),
    }


def griffin_decode(
    params: dict, cfg: RGLRUConfig, x: jax.Array, state: dict
) -> tuple[jax.Array, dict]:
    """One-token decode. x (B, 1, D) → (B, 1, D), new state."""
    dtype = x.dtype
    xt = x[:, 0]
    gate = jax.nn.gelu(xt @ params["w_gate"].astype(dtype))
    u = xt @ params["w_x"].astype(dtype)  # (B, R)
    # causal conv over [state.conv | u]
    hist = jnp.concatenate([state["conv"], u[:, None]], axis=1)  # (B, K, R)
    w = params["conv"].astype(dtype)
    u_c = jnp.einsum("bkr,kr->br", hist, w)
    u32 = u_c.astype(jnp.float32)
    r_g = jax.nn.sigmoid(u32 @ params["w_a"] + params["b_a"][None])
    i_g = jax.nn.sigmoid(u32 @ params["w_i"] + params["b_i"][None])
    a = jnp.exp(_C_RGLRU * r_g * jax.nn.log_sigmoid(params["lam"])[None])
    h = a * state["h"] + jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * (i_g * u32)
    out = (gate * h.astype(dtype)) @ params["w_out"].astype(dtype)
    new_state = {"h": h, "conv": hist[:, 1:]}
    return out[:, None], new_state


# ===========================================================================
# mLSTM (xLSTM's matrix-memory cell) — chunkwise-parallel
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class MLSTMConfig:
    d_model: int
    n_heads: int
    d_head: int  # = d_inner / n_heads
    expand: float = 2.0
    chunk: int = 256
    conv_width: int = 4


def init_mlstm(key: jax.Array, cfg: MLSTMConfig) -> dict:
    d = cfg.d_model
    di = cfg.n_heads * cfg.d_head
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, 2 * di)),  # (inner, gate)
        "w_down": dense_init(ks[1], (di, d)),
        "conv": dense_init(ks[2], (cfg.conv_width, di)) * 0.1,
        "wq": dense_init(ks[3], (di, di)).reshape(di, cfg.n_heads, cfg.d_head),
        "wk": dense_init(ks[4], (di, di)).reshape(di, cfg.n_heads, cfg.d_head),
        "wv": dense_init(ks[5], (di, di)).reshape(di, cfg.n_heads, cfg.d_head),
        "w_if": dense_init(ks[6], (di, 2 * cfg.n_heads)),  # input/forget gates
        "b_if": jnp.concatenate(
            [jnp.zeros((cfg.n_heads,)), 3.0 * jnp.ones((cfg.n_heads,))]
        ),
        "skip_scale": jnp.ones((di,), jnp.float32),
        "out_norm": {"scale": jnp.ones((di,), jnp.float32)},
    }


def _mlstm_chunk_parallel(q, k, v, log_i, log_f, chunk=256):
    """Stabilized chunkwise mLSTM.

    q,k,v: (B, H, T, d); log_i/log_f: (B, H, T). Returns (B, H, T, d).

    Within a chunk: masked quadratic attention with gate-derived decay
    weights; across chunks: recurrent (C, n, m) state carry — both exact
    (same math as the sequential form, reassociated).
    """
    B, H, T, d = q.shape
    C = chunk if (chunk and T % chunk == 0) else T  # chunk length
    n_chunks = T // C
    qs = q.reshape(B, H, n_chunks, C, d)
    ks_ = k.reshape(B, H, n_chunks, C, d)
    vs = v.reshape(B, H, n_chunks, C, d)
    li = log_i.reshape(B, H, n_chunks, C)
    lf = log_f.reshape(B, H, n_chunks, C)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    def chunk_step(carry, xs):
        Cst, nst, mst = carry  # (B,H,d,d), (B,H,d), (B,H)
        qc, kc, vc, lic, lfc = xs  # (B,H,C,d), ..., (B,H,C)
        csum_f = jnp.cumsum(lfc, axis=-1)  # (B,H,C) Σ_{s≤t} log f_s
        total_f = csum_f[..., -1]
        # intra-chunk decay: D[t,s] = exp(csum_f[t] − csum_f[s] + li[s]), s ≤ t
        log_D = (
            csum_f[..., :, None] - csum_f[..., None, :] + lic[..., None, :]
        )  # (B,H,C,C)
        mask = jnp.tril(jnp.ones((C, C), bool))
        log_D = jnp.where(mask[None, None], log_D, -jnp.inf)
        # inter-chunk contribution decay for queries: exp(csum_f[t] + m_prev)
        log_carry = csum_f + mst[..., None]  # (B,H,C)
        m_t = jnp.maximum(jnp.max(log_D, axis=-1), log_carry)  # (B,H,C)
        m_t = jnp.maximum(m_t, -1e30)
        Dw = jnp.exp(log_D - m_t[..., None])  # (B,H,C,C)
        s_qk = jnp.einsum("bhtd,bhsd->bhts", qc, kc * scale)
        intra = jnp.einsum("bhts,bhsd->bhtd", s_qk * Dw, vc)
        inter_w = jnp.exp(log_carry - m_t)  # (B,H,C)
        q_dec = qc * inter_w[..., None]
        inter = jnp.einsum("bhtd,bhde->bhte", q_dec, Cst)
        denom_raw = jnp.einsum("bhtd,bhd->bht", q_dec, nst) + jnp.sum(
            s_qk * Dw, axis=-1
        )
        denom = jnp.maximum(jnp.abs(denom_raw), jnp.exp(-m_t))
        h = (intra + inter) / denom[..., None]
        # state update: C' = f_total C + Σ_s exp(Σ_{u>s} f + i_s) k_s v_sᵀ
        m_next = jnp.maximum(
            total_f + mst,
            jnp.max(lic + total_f[..., None] - csum_f, axis=-1),
        )
        w_state = jnp.exp(
            lic + total_f[..., None] - csum_f - m_next[..., None]
        )  # (B,H,C)
        decay = jnp.exp(total_f + mst - m_next)
        k_s = kc * scale
        C_new = decay[..., None, None] * Cst + jnp.einsum(
            "bhs,bhsd,bhse->bhde", w_state, k_s, vc
        )
        n_new = decay[..., None] * nst + jnp.einsum("bhs,bhsd->bhd", w_state, k_s)
        return (C_new, n_new, m_next), h

    init = (
        jnp.zeros((B, H, d, d), jnp.float32),
        jnp.zeros((B, H, d), jnp.float32),
        jnp.full((B, H), -1e30, jnp.float32),
    )
    xs = (
        jnp.moveaxis(qs, 2, 0),
        jnp.moveaxis(ks_, 2, 0),
        jnp.moveaxis(vs, 2, 0),
        jnp.moveaxis(li, 2, 0),
        jnp.moveaxis(lf, 2, 0),
    )
    _, hs = jax.lax.scan(chunk_step, init, xs)  # (n_chunks, B, H, C, d)
    return jnp.moveaxis(hs, 0, 2).reshape(B, H, T, d)


def mlstm(params: dict, cfg: MLSTMConfig, x: jax.Array) -> jax.Array:
    """mLSTM block over x (B, T, D) → (B, T, D)."""
    from repro.models.layers import rms_norm

    dtype = x.dtype
    B, T, D = x.shape
    up = x @ params["w_up"].astype(dtype)  # (B, T, 2·di)
    inner, gate = jnp.split(up, 2, axis=-1)
    inner = _causal_conv(params["conv"].astype(dtype), inner)
    inner_act = jax.nn.silu(inner)
    q = jnp.einsum("btd,dhk->bhtk", inner_act, params["wq"].astype(dtype))
    k = jnp.einsum("btd,dhk->bhtk", inner_act, params["wk"].astype(dtype))
    v = jnp.einsum("btd,dhk->bhtk", inner, params["wv"].astype(dtype))
    gf = (inner.astype(jnp.float32) @ params["w_if"]) + params["b_if"][None, None]
    log_i, log_f = jnp.split(gf, 2, axis=-1)  # (B, T, H) each
    log_i = jnp.moveaxis(log_i, -1, 1)  # (B, H, T)
    log_f = jnp.moveaxis(jax.nn.log_sigmoid(log_f), -1, 1)
    h = _mlstm_chunk_parallel(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        log_i, log_f, chunk=cfg.chunk,
    )  # (B, H, T, d)
    h = jnp.moveaxis(h, 1, 2).reshape(B, T, -1).astype(dtype)
    h = rms_norm(params["out_norm"], h)
    h = h + params["skip_scale"].astype(dtype)[None, None] * inner_act
    h = h * jax.nn.silu(gate)
    return h @ params["w_down"].astype(dtype)


def init_mlstm_state(cfg: MLSTMConfig, batch: int) -> dict:
    H, d = cfg.n_heads, cfg.d_head
    return {
        "C": jnp.zeros((batch, H, d, d), jnp.float32),
        "n": jnp.zeros((batch, H, d), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, H * d), jnp.float32),
    }


def mlstm_decode(
    params: dict, cfg: MLSTMConfig, x: jax.Array, state: dict
) -> tuple[jax.Array, dict]:
    """One-token mLSTM step. x (B, 1, D)."""
    from repro.models.layers import rms_norm

    dtype = x.dtype
    B = x.shape[0]
    up = x[:, 0] @ params["w_up"].astype(dtype)
    inner, gate = jnp.split(up, 2, axis=-1)
    hist = jnp.concatenate([state["conv"].astype(dtype), inner[:, None]], axis=1)
    w = params["conv"].astype(dtype)
    inner_c = jnp.einsum("bkr,kr->br", hist, w)
    inner_act = jax.nn.silu(inner_c)
    q = jnp.einsum("bd,dhk->bhk", inner_act, params["wq"].astype(dtype)).astype(jnp.float32)
    k = jnp.einsum("bd,dhk->bhk", inner_act, params["wk"].astype(dtype)).astype(jnp.float32)
    v = jnp.einsum("bd,dhk->bhk", inner_c, params["wv"].astype(dtype)).astype(jnp.float32)
    gf = (inner_c.astype(jnp.float32) @ params["w_if"]) + params["b_if"][None]
    log_i, log_f_raw = jnp.split(gf, 2, axis=-1)  # (B, H)
    log_f = jax.nn.log_sigmoid(log_f_raw)
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))
    m_new = jnp.maximum(log_f + state["m"], log_i)
    i_w = jnp.exp(log_i - m_new)
    f_w = jnp.exp(log_f + state["m"] - m_new)
    k_s = k * scale
    C_new = f_w[..., None, None] * state["C"] + i_w[..., None, None] * (
        k_s[..., :, None] * v[..., None, :]
    )
    n_new = f_w[..., None] * state["n"] + i_w[..., None] * k_s
    num = jnp.einsum("bhk,bhke->bhe", q, C_new)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", q, n_new))
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]  # (B, H, d)
    h = h.reshape(B, -1).astype(dtype)
    h = rms_norm(params["out_norm"], h)
    h = h + params["skip_scale"].astype(dtype)[None] * inner_act
    h = h * jax.nn.silu(gate)
    out = h @ params["w_down"].astype(dtype)
    new_state = {"C": C_new, "n": n_new, "m": m_new, "conv": hist[:, 1:].astype(jnp.float32)}
    return out[:, None], new_state


# ===========================================================================
# sLSTM (xLSTM's scalar cell with exponential gating + head mixing)
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class SLSTMConfig:
    d_model: int
    n_heads: int
    d_head: int


def init_slstm(key: jax.Array, cfg: SLSTMConfig) -> dict:
    d = cfg.d_model
    di = cfg.n_heads * cfg.d_head
    ks = jax.random.split(key, 4)
    return {
        # fused input proj for gates (i, f, z, o)
        "w_in": dense_init(ks[0], (d, 4 * di)),
        # block-diagonal (per-head) recurrent mixing for each gate
        "r_in": dense_init(ks[1], (4, cfg.n_heads, cfg.d_head, cfg.d_head))
        * 0.5,
        "b": jnp.concatenate(
            [
                jnp.zeros((di,)),  # i
                3.0 * jnp.ones((di,)),  # f (open at init)
                jnp.zeros((2 * di,)),  # z, o
            ]
        ),
        "w_down": dense_init(ks[2], (di, d)),
        "out_norm": {"scale": jnp.ones((di,), jnp.float32)},
    }


def _slstm_step(params, cfg: SLSTMConfig, state, wx_t):
    """One sLSTM step. wx_t: (B, 4·di) pre-computed input projection."""
    c, n, h, m = state  # (B, H, d) ×3, (B, H)
    B = wx_t.shape[0]
    H, d = cfg.n_heads, cfg.d_head
    rh = jnp.einsum("bhk,ghkl->bghl", h, params["r_in"])  # (B, 4, H, d)
    z_all = wx_t.reshape(B, 4, H, d) + rh + params["b"].reshape(1, 4, H, d)
    i_t, f_t, z_t, o_t = z_all[:, 0], z_all[:, 1], z_all[:, 2], z_all[:, 3]
    log_i = i_t.mean(-1)  # scalar gates per head (B, H)
    log_f = jax.nn.log_sigmoid(f_t.mean(-1))
    m_new = jnp.maximum(log_f + m, log_i)
    i_w = jnp.exp(log_i - m_new)[..., None]
    f_w = jnp.exp(log_f + m - m_new)[..., None]
    c_new = f_w * c + i_w * jnp.tanh(z_t)
    n_new = f_w * n + i_w
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm(params: dict, cfg: SLSTMConfig, x: jax.Array) -> jax.Array:
    """sLSTM over x (B, T, D) → (B, T, D) via sequential scan."""
    from repro.models.layers import rms_norm

    dtype = x.dtype
    B, T, D = x.shape
    wx = (x @ params["w_in"].astype(dtype)).astype(jnp.float32)  # (B, T, 4di)
    H, d = cfg.n_heads, cfg.d_head
    init = (
        jnp.zeros((B, H, d), jnp.float32),
        jnp.zeros((B, H, d), jnp.float32),
        jnp.zeros((B, H, d), jnp.float32),
        jnp.full((B, H), -1e30, jnp.float32),
    )
    _, hs = jax.lax.scan(
        lambda s, w: _slstm_step(params, cfg, s, w), init, jnp.moveaxis(wx, 1, 0)
    )  # (T, B, H, d)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, T, H * d).astype(dtype)
    h = rms_norm(params["out_norm"], h)
    return h @ params["w_down"].astype(dtype)


def init_slstm_state(cfg: SLSTMConfig, batch: int) -> tuple:
    H, d = cfg.n_heads, cfg.d_head
    return (
        jnp.zeros((batch, H, d), jnp.float32),
        jnp.zeros((batch, H, d), jnp.float32),
        jnp.zeros((batch, H, d), jnp.float32),
        jnp.full((batch, H), -1e30, jnp.float32),
    )


def slstm_decode(
    params: dict, cfg: SLSTMConfig, x: jax.Array, state: tuple
) -> tuple[jax.Array, tuple]:
    """One-token sLSTM step. x (B, 1, D)."""
    from repro.models.layers import rms_norm

    dtype = x.dtype
    wx = (x[:, 0] @ params["w_in"].astype(dtype)).astype(jnp.float32)
    new_state, h = _slstm_step(params, cfg, state, wx)
    B = x.shape[0]
    h = h.reshape(B, -1).astype(dtype)
    h = rms_norm(params["out_norm"], h)
    out = h @ params["w_down"].astype(dtype)
    return out[:, None], new_state
