"""Serving: batched prefill + one-token decode steps (pjit-ready).

``make_prefill_step`` — forward over the full prompt, emits last-token
logits (the dry-run's `prefill_*` cells lower this).
``make_serve_step``   — one new token against a seq_len-deep KV cache /
recurrent state (the `decode_*` / `long_*` cells lower this); cache tensors
are donated by the launcher so decode is in-place in HBM.
``greedy_generate``   — host loop driving serve_step for the examples.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import decode_step, init_serve_state, prefill
from repro.models.config import ModelConfig

__all__ = ["make_prefill_step", "make_serve_step", "greedy_generate"]


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        _, logits = prefill(params, cfg, batch)
        return logits

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, state, batch):
        return decode_step(params, cfg, state, batch)

    return serve_step


def greedy_generate(
    params,
    cfg: ModelConfig,
    prompt_tokens: jax.Array,
    max_new: int,
    max_len: int | None = None,
) -> jax.Array:
    """Greedy decoding for token-frontend models (host loop, jit step)."""
    B, T = prompt_tokens.shape
    max_len = max_len or (T + max_new)
    state = init_serve_state(cfg, B, max_len)
    step = jax.jit(make_serve_step(cfg))

    # teacher-force the prompt through the decode path (builds the cache)
    logits = None
    for t in range(T):
        logits, state = step(params, state, {"tokens": prompt_tokens[:, t : t + 1]})

    out = [prompt_tokens]
    cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(max_new):
        out.append(cur)
        logits, state = step(params, state, {"tokens": cur})
        cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)
