"""Coreset-as-a-service: submit pool deltas, get back (indices, γ, version).

The minimal service surface over the streaming selection stack
(DESIGN.md §10): a ``CoresetService`` owns

  * a :class:`~repro.core.engines.streaming.StreamingSelector` — the
    sieve-streaming state machine (O(Δn·k) per delta, no re-sweep);
  * the accumulated pool buffer (finalization needs the rows the selected
    indices point at — the only per-pool-size memory in the stack);
  * an :class:`~repro.core.refresh.AsyncRefresher` in ingest mode — deltas
    submitted while a job is in flight coalesce into the next drain, and
    every drain publishes one versioned selection through the same
    single-slot / ``on_complete`` lifecycle the trainer's refreshes use;
  * a staged→installed double buffer mirroring ``CoresetSampler``'s
    semantics: drains *stage* the newest selection, :meth:`coreset`
    *installs* it at the caller's boundary — readers never observe a
    half-written update.

``launch/serve.py --coreset`` wraps this in a JSON-lines stdin/stdout
protocol; tests drive it in-process and as a subprocess round-trip.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Literal

import numpy as np

from repro.core.engines.streaming import StreamingConfig, StreamingSelector
from repro.core.refresh import AsyncRefresher, RefreshResult
from repro.faults import FailurePolicy, fault_point

__all__ = ["CoresetService", "CoresetUpdate"]


def _no_submit(_params):  # pragma: no cover - guard, never runs in tests
    raise RuntimeError(
        "CoresetService drives its refresher through the ingest path; "
        "submit() has no meaning here"
    )


@dataclasses.dataclass(frozen=True)
class CoresetUpdate:
    """One installed selection: what a service client trains on.

    ``version`` is the refresher's drain counter (one per coalesced ingest
    job, monotone); ``n_seen`` the pool size the selection covers;
    ``weights`` the γ cluster sizes (Σγ == n_live); ``n_live`` the rows
    surviving eviction (== n_seen unless the service evicts).  ``indices``
    are always global arrival positions, eviction or not.
    """

    version: int
    indices: np.ndarray
    weights: np.ndarray
    coverage: float
    n_seen: int
    n_live: int = -1


class CoresetService:
    """Submit pool deltas; read back the current (indices, γ, version).

    Args:
      budget: coreset size k — fixed for the service lifetime (the sieve
        capacity is baked into the state shapes).
      dim: proxy-feature dimension of arriving deltas.
      config: streaming engine knobs (sieve grid density).
      metric: 'l2' | 'cosine' (cosine via unit-normalized l2).
      per_class: stratified per-class budgets ∝ observed class arrival
        (paper §5); deltas must then carry labels.
      mode: 'sync' — drains run inline in :meth:`submit_delta` (the
        deterministic baseline); 'async' — drains run on the refresher's
        worker thread and coalesce while it is busy.
      evict: drop pool rows no sieve references after every drain — the
        pool buffer (and the serialized snapshot) stays O(L·k·d) instead
        of O(n·d) for unbounded streams.  Published indices stay global
        arrival positions either way; γ then sums to ``n_live``.
      failure_policy: retry/backoff/exhaustion for ingest drains
        (DESIGN.md §12).  Drains are transactional — a failed attempt
        restores the selector + pool to their pre-drain snapshot, so a
        retry replays the same deltas against the same state.  Under
        ``on_exhaustion='keep_stale'`` the failure is recorded
        (:meth:`pop_failure`) instead of raising, and the service keeps
        serving the previously installed selection.
    """

    def __init__(
        self,
        budget: int,
        dim: int,
        *,
        config: StreamingConfig | None = None,
        metric: str = "l2",
        per_class: bool = False,
        mode: Literal["sync", "async"] = "sync",
        evict: bool = False,
        failure_policy: FailurePolicy | None = None,
    ):
        self.budget = int(budget)
        self.dim = int(dim)
        self.evict = bool(evict)
        self.selector = StreamingSelector(
            budget, dim, config=config, metric=metric, per_class=per_class,
            evict=evict,
        )
        self._pool: list[np.ndarray] = []  # deltas in ingest order (worker-owned)
        self._lock = threading.Lock()
        self._staged: CoresetUpdate | None = None
        self._installed: CoresetUpdate | None = None
        self._failures: list[dict] = []  # keep_stale abandonments (worker-fed)
        self.refresher = AsyncRefresher(
            _no_submit, mode=mode,
            ingest_fn=self._ingest_job, on_complete=self._stage,
            failure_policy=failure_policy, on_failure=self._note_failure,
        )

    # -- lifecycle -----------------------------------------------------------

    def submit_delta(self, feats, labels=None) -> int | None:
        """Queue one (Δn, dim) delta; returns the drained version, or None
        if it coalesced behind an in-flight job (async mode)."""
        feats = np.asarray(feats, np.float32)
        if feats.ndim != 2 or feats.shape[1] != self.dim:
            raise ValueError(f"expected (Δn, {self.dim}) features, got {feats.shape}")
        labels = None if labels is None else np.asarray(labels).ravel()
        return self.refresher.ingest((feats, labels))

    def coreset(self, block: bool = True) -> CoresetUpdate | None:
        """Install and return the newest published selection.

        ``block=True`` drains any queued/in-flight ingests first (worker
        failures re-raise here).  Returns None if nothing has been
        published yet.
        """
        if block:
            self.refresher.wait()
        with self._lock:
            if self._staged is not None:
                self._installed, self._staged = self._staged, None
            return self._installed

    @property
    def version(self) -> int:
        """Version of the most recently *installed* selection (0 = none)."""
        with self._lock:
            return 0 if self._installed is None else self._installed.version

    @property
    def n_seen(self) -> int:
        """Pool size ingested so far (includes staged-but-not-installed)."""
        return self.selector.n_seen

    def pop_failure(self) -> dict | None:
        """Pop the oldest recorded keep_stale abandonment, if any.

        The stdio server (``launch/serve.py``) checks this after every
        delta so a client sees an explicit ``craig_refresh_failed`` event
        instead of a silently unchanged version."""
        with self._lock:
            return self._failures.pop(0) if self._failures else None

    # -- worker side ---------------------------------------------------------

    def _ingest_job(self, deltas: list):
        """One coalesced drain: ingest every queued delta, (optionally)
        evict dead pool rows, finalize once.

        Transactional: the selector state and pool buffer snapshot up
        front and restore on ANY failure, so a retry (or the next drain
        after a keep_stale abandonment) replays against unpoisoned state —
        a half-applied delta can never leak into the sieve.
        """
        fault_point("service.ingest", n_deltas=len(deltas))
        snap = self.selector.state_dict()
        pool_snap = list(self._pool)
        try:
            for feats, labels in deltas:
                self.selector.ingest(feats, labels=labels)
                self._pool.append(feats)
            pool = np.concatenate(self._pool, axis=0)
            if self.evict:
                keep = self.selector.compact()
                pool = np.ascontiguousarray(pool[keep])
                self._pool = [pool]
            res = self.selector.result(pool)
            indices = np.asarray(res.indices, np.int64)
            if self.evict:  # live-pool positions → global arrival ids
                indices = self.selector.live_ids[indices]
        except BaseException:
            self.selector.load_state_dict(snap)
            self._pool = pool_snap
            raise
        return (
            indices,
            np.asarray(res.weights, np.float32),
            float(res.coverage),
            self.selector.n_rows,
        )

    def _note_failure(self, res: RefreshResult) -> None:
        """on_failure hook (keep_stale): record the abandoned drain."""
        err = res.error
        with self._lock:
            self._failures.append(
                {
                    "event": "craig_refresh_failed",
                    "version": res.version,
                    "attempts": res.attempts,
                    "error": f"{type(err).__name__}: {err}",
                }
            )

    def _stage(self, res: RefreshResult) -> None:
        indices, weights, coverage, n_live = res.value
        with self._lock:
            self._staged = CoresetUpdate(
                version=res.version,
                indices=indices,
                weights=weights,
                coverage=coverage,
                n_seen=self.selector.n_seen,
                n_live=n_live,
            )

    # -- serialization -------------------------------------------------------

    def state_dict(self) -> dict:
        """JSON-able snapshot: selector state + pool buffer + install state.

        Callers drain (``coreset(block=True)``) before snapshotting, same
        as the trainer's checkpoint discipline — an in-flight drain always
        materializes before the save.  With ``evict=True`` every drain
        compacts the pool first, so the serialized pool holds only live
        rows — O(L·k·d) text, not O(n·d).
        """
        self.refresher.wait()
        with self._lock:
            installed = self._installed
        return {
            "selector": self.selector.state_dict(),
            "pool": [d.tolist() for d in self._pool],
            "installed": None
            if installed is None
            else {
                "version": installed.version,
                "indices": installed.indices.tolist(),
                "weights": installed.weights.tolist(),
                "coverage": installed.coverage,
                "n_seen": installed.n_seen,
                "n_live": installed.n_live,
            },
        }

    def load_state_dict(self, d: dict) -> None:
        self.selector.load_state_dict(d["selector"])
        self._pool = [
            np.asarray(p, np.float32).reshape(-1, self.dim) for p in d["pool"]
        ]
        inst = d["installed"]
        with self._lock:
            self._staged = None
            self._installed = (
                None
                if inst is None
                else CoresetUpdate(
                    version=int(inst["version"]),
                    indices=np.asarray(inst["indices"], np.int64),
                    weights=np.asarray(inst["weights"], np.float32),
                    coverage=float(inst["coverage"]),
                    n_seen=int(inst["n_seen"]),
                    n_live=int(inst.get("n_live", inst["n_seen"])),
                )
            )
        self.refresher.reset_version(self.version)
