"""Serving substrate: prefill/decode steps + generation loop."""
from repro.serve.serve_step import greedy_generate, make_prefill_step, make_serve_step

__all__ = ["greedy_generate", "make_prefill_step", "make_serve_step"]
