"""Serving substrate: prefill/decode steps + generation loop, plus the
coreset service (streaming selection behind a versioned delta API)."""
from repro.serve.coreset_service import CoresetService, CoresetUpdate
from repro.serve.serve_step import greedy_generate, make_prefill_step, make_serve_step

__all__ = [
    "CoresetService",
    "CoresetUpdate",
    "greedy_generate",
    "make_prefill_step",
    "make_serve_step",
]
