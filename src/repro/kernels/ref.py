"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["fl_gains_ref", "pairwise_l2_ref", "ce_proxy_ref", "topk_sim_ref"]


def pairwise_l2_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """(n, m) pairwise Euclidean distances, fp32."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    sqx = jnp.sum(x * x, axis=1)[:, None]
    sqy = jnp.sum(y * y, axis=1)[None, :]
    d2 = sqx + sqy - 2.0 * x @ y.T
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def fl_gains_ref(
    x: jax.Array, e: jax.Array, cur_max: jax.Array, d_max: jax.Array
) -> jax.Array:
    """gains[c] = Σ_i relu((d_max − ‖x_i − e_c‖) − cur_max_i), fp32 (m,)."""
    dist = pairwise_l2_ref(x, e)  # (n, m)
    sim = d_max - dist
    return jnp.sum(
        jnp.maximum(sim - cur_max.astype(jnp.float32)[:, None], 0.0), axis=0
    )


def topk_sim_ref(
    x: jax.Array, k: int, d_max: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Dense top-k similarity rows: vals (n, k) desc, idx (n, k) int32.

    sim[i, j] = d_max − ‖x_i − x_j‖; ties broken by ascending column index
    (lax.top_k is stable), matching the blocked Pallas builder.
    """
    sim = d_max - pairwise_l2_ref(x, x)
    vals, idx = jax.lax.top_k(sim, k)
    return vals.astype(jnp.float32), idx.astype(jnp.int32)


def ce_proxy_ref(
    hidden: jax.Array, unembed: jax.Array, labels: jax.Array
) -> jax.Array:
    """g_t = (softmax(h_t W) − onehot(y_t)) @ Wᵀ, fp32 (T, D)."""
    h = hidden.astype(jnp.float32)
    w = unembed.astype(jnp.float32)
    logits = h @ w  # (T, V)
    p = jax.nn.softmax(logits, axis=-1)
    delta = p - jax.nn.one_hot(labels, w.shape[1], dtype=jnp.float32)
    return delta @ w.T
