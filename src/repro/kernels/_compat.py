"""Version shims for Pallas TPU APIs across jax releases.

Kernel modules import ``pltpu`` and ``tpu_params`` from here so the
CompilerParams (jax ≥ 0.6) vs TPUCompilerParams (0.4.x) spelling — and any
future rename — is handled in exactly one place.
"""
from __future__ import annotations

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover - non-TPU builds
    pltpu = None

__all__ = ["pltpu", "tpu_params"]


def tpu_params(*dimension_semantics: str):
    """TPU compiler params for ``pl.pallas_call`` (None when unavailable;
    the interpreter ignores them either way)."""
    if pltpu is None:
        return None
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None
    )
    if cls is None:
        return None
    return cls(dimension_semantics=tuple(dimension_semantics))
