"""Pallas TPU kernel: fused facility-location marginal gains (CRAIG hot-spot).

One greedy step of CRAIG (paper Alg. 1 line 3) evaluates, for every candidate
e, the marginal gain

    gain(e) = Σ_i relu( s_ie − cur_max_i ),     s_ie = d_max − ‖x_i − x_e‖

over the whole pool i ∈ V.  Done naively this materializes an (n, m)
similarity matrix in HBM per step.  This kernel fuses

    pairwise-distance (MXU matmul x·eᵀ + rank-1 squared-norm terms)
      → similarity → subtract running max → relu → reduce over n

entirely in VMEM, tiled (block_n × block_m), accumulating the n-reduction
across grid steps into the (1, block_m) output tile.  Arithmetic intensity is
that of a matmul with a free epilogue — the MXU term dominates.

Inputs are pre-arranged by :mod:`repro.kernels.ops`:
  x      (n, d)   pool proxy features (fp32), d padded to a lane multiple
  e      (m, d)   candidate features
  madj   (n, 1)   d_max − cur_max_i   (similarity headroom per point)
  sqx    (n, 1)   ‖x_i‖²
  sqe    (1, m)   ‖x_e‖²
Output:
  gains  (1, m)   fp32

TPU mapping notes (DESIGN.md §2): block shapes default to (512, 256) with the
full proxy dim d resident (d ≤ 8·128 after padding); all matmul dims are
multiples of 128 so the 128×128 MXU tiles are dense.  The n-grid axis is the
inner (fastest) axis so the output tile stays resident while the reduction
accumulates ("revisiting" accumulation pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import tpu_params

_TPU_PARAMS = tpu_params("parallel", "arbitrary")

__all__ = ["fl_gains_pallas"]


def _fl_gains_kernel(x_ref, e_ref, madj_ref, sqx_ref, sqe_ref, out_ref):
    """Grid = (m_blocks, n_blocks); n is the inner reduction axis."""
    ni = pl.program_id(1)

    @pl.when(ni == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]  # (bn, d)
    e = e_ref[...]  # (bm, d)
    # Squared distance via the MXU: ‖x−e‖² = ‖x‖² + ‖e‖² − 2 x·e
    dots = jax.lax.dot_general(
        x,
        e,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bn, bm)
    d2 = sqx_ref[...] + sqe_ref[...] - 2.0 * dots
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    # gain contribution: relu((d_max − cur_max) − dist)
    contrib = jnp.maximum(madj_ref[...] - dist, 0.0)  # (bn, bm)
    out_ref[...] += jnp.sum(contrib, axis=0, keepdims=True)  # (1, bm)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret")
)
def fl_gains_pallas(
    x: jax.Array,
    e: jax.Array,
    madj: jax.Array,
    sqx: jax.Array,
    sqe: jax.Array,
    *,
    block_n: int = 512,
    block_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Blocked fused FL gains. Shapes must already be block-aligned.

    Args:
      x: (n, d) fp32, n % block_n == 0, d % 128 == 0.
      e: (m, d) fp32, m % block_m == 0.
      madj: (n, 1) fp32 = d_max − cur_max.
      sqx: (n, 1) fp32 squared norms of x.
      sqe: (1, m) fp32 squared norms of e.
    Returns:
      (m,) fp32 gains.
    """
    n, d = x.shape
    m = e.shape[0]
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    grid = (m // block_m, n // block_n)
    out = pl.pallas_call(
        _fl_gains_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda mi, ni: (ni, 0)),
            pl.BlockSpec((block_m, d), lambda mi, ni: (mi, 0)),
            pl.BlockSpec((block_n, 1), lambda mi, ni: (ni, 0)),
            pl.BlockSpec((block_n, 1), lambda mi, ni: (ni, 0)),
            pl.BlockSpec((1, block_m), lambda mi, ni: (0, mi)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda mi, ni: (0, mi)),
        out_shape=jax.ShapeDtypeStruct((1, m), jnp.float32),
        compiler_params=_TPU_PARAMS,
        interpret=interpret,
    )(
        x.astype(jnp.float32),
        e.astype(jnp.float32),
        madj.astype(jnp.float32),
        sqx.astype(jnp.float32),
        sqe.astype(jnp.float32),
    )
    return out[0]
