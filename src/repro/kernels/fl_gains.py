"""Pallas TPU kernels: fused facility-location marginal gains (CRAIG hot-spot).

One greedy step of CRAIG (paper Alg. 1 line 3) evaluates, for every candidate
e, the marginal gain

    gain(e) = Σ_i relu( s_ie − cur_max_i ),     s_ie = d_max − ‖x_i − x_e‖

over the whole pool i ∈ V.  Done naively this materializes an (n, m)
similarity matrix in HBM per step.  ``fl_gains_pallas`` fuses

    pairwise-distance (MXU matmul x·eᵀ + rank-1 squared-norm terms)
      → similarity → subtract running max → relu → reduce over n

entirely in VMEM, tiled (block_n × block_m), accumulating the n-reduction
across grid steps into the (1, block_m) output tile.  Arithmetic intensity is
that of a matmul with a free epilogue — the MXU term dominates.

``fl_gains_argmax_pallas`` (DESIGN.md §2, §3.6) extends the same sweep with a
fused argmax epilogue for the device-resident greedy engine: the gains tile
accumulates in a VMEM scratch buffer instead of the output, and on the last
n-step each candidate block reduces itself to a single
``(best_gain, best_index)`` partial (max-reduce + first-hit index extraction —
no argmax primitive, same idiom as ``topk_sim``).  One kernel launch per
greedy round replaces the gains-materialize + separate argmax pair; the
host-side finalize is an O(m/block_m) reduction over the partials.
Already-selected candidates are excluded *inside* the epilogue via an
additive ``penalty`` row (−1e30 on chosen/padded columns), so no masked
(1, m) gains vector ever exists.

Inputs are pre-arranged by :mod:`repro.kernels.ops`:
  x      (n, d)   pool proxy features (fp32 or bf16), d padded to a lane
                  multiple
  e      (m, d)   candidate features (same dtype as x)
  madj   (n, 1)   d_max − cur_max_i   (similarity headroom per point, fp32)
  sqx    (n, 1)   ‖x_i‖²  (fp32)
  sqe    (1, m)   ‖x_e‖²  (fp32)
  penalty (1, m)  0 for live candidates, −1e30 for chosen/padded columns
                  (argmax variant only)
Outputs:
  gains  (1, m)   fp32                       (fl_gains_pallas)
  gains (1, m) + best_g (1, m_blocks) fp32 + best_i (1, m_blocks) int32
                                             (fl_gains_argmax_pallas)

TPU mapping notes (DESIGN.md §2): block shapes default to (512, 256) with the
full proxy dim d resident (d ≤ 8·128 after padding); all matmul dims are
multiples of 128 so the 128×128 MXU tiles are dense.  The n-grid axis is the
inner (fastest) axis so the output tile (or the scratch accumulator) stays
resident while the reduction accumulates ("revisiting" accumulation pattern).
Tiles may be bf16 (MXU-native) while distances, gains, and the running
accumulation stay fp32 (``preferred_element_type``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import tpu_params

_TPU_PARAMS = tpu_params("parallel", "arbitrary")

__all__ = ["fl_gains_pallas", "fl_gains_argmax_pallas"]


def _first_hit(values: jax.Array, target: jax.Array) -> jax.Array:
    """Lowest column position where ``values`` equals per-row ``target``.

    values: (r, w); target: (r, 1).  Returns (r, 1) int32 positions — the
    no-argmax-primitive idiom shared with ``topk_sim`` (DESIGN.md §2).
    """
    w = values.shape[1]
    pos = jax.lax.broadcasted_iota(jnp.int32, values.shape, 1)
    return jnp.min(jnp.where(values == target, pos, w), axis=1, keepdims=True)


def _fl_gains_kernel(x_ref, e_ref, madj_ref, sqx_ref, sqe_ref, out_ref):
    """Grid = (m_blocks, n_blocks); n is the inner reduction axis."""
    ni = pl.program_id(1)

    @pl.when(ni == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]  # (bn, d)
    e = e_ref[...]  # (bm, d)
    # Squared distance via the MXU: ‖x−e‖² = ‖x‖² + ‖e‖² − 2 x·e
    dots = jax.lax.dot_general(
        x,
        e,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bn, bm)
    d2 = sqx_ref[...] + sqe_ref[...] - 2.0 * dots
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    # gain contribution: relu((d_max − cur_max) − dist)
    contrib = jnp.maximum(madj_ref[...] - dist, 0.0)  # (bn, bm)
    out_ref[...] += jnp.sum(contrib, axis=0, keepdims=True)  # (1, bm)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret")
)
def fl_gains_pallas(
    x: jax.Array,
    e: jax.Array,
    madj: jax.Array,
    sqx: jax.Array,
    sqe: jax.Array,
    *,
    block_n: int = 512,
    block_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Blocked fused FL gains. Shapes must already be block-aligned.

    Args:
      x: (n, d) fp32, n % block_n == 0, d % 128 == 0.
      e: (m, d) fp32, m % block_m == 0.
      madj: (n, 1) fp32 = d_max − cur_max.
      sqx: (n, 1) fp32 squared norms of x.
      sqe: (1, m) fp32 squared norms of e.
    Returns:
      (m,) fp32 gains.
    """
    n, d = x.shape
    m = e.shape[0]
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    grid = (m // block_m, n // block_n)
    out = pl.pallas_call(
        _fl_gains_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda mi, ni: (ni, 0)),
            pl.BlockSpec((block_m, d), lambda mi, ni: (mi, 0)),
            pl.BlockSpec((block_n, 1), lambda mi, ni: (ni, 0)),
            pl.BlockSpec((block_n, 1), lambda mi, ni: (ni, 0)),
            pl.BlockSpec((1, block_m), lambda mi, ni: (0, mi)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda mi, ni: (0, mi)),
        out_shape=jax.ShapeDtypeStruct((1, m), jnp.float32),
        compiler_params=_TPU_PARAMS,
        interpret=interpret,
    )(
        x.astype(jnp.float32),
        e.astype(jnp.float32),
        madj.astype(jnp.float32),
        sqx.astype(jnp.float32),
        sqe.astype(jnp.float32),
    )
    return out[0]


def _make_argmax_kernel(block_m: int):
    def kernel(
        x_ref, e_ref, madj_ref, sqx_ref, sqe_ref, pen_ref,
        gains_ref, bg_ref, bi_ref,
    ):
        """Grid = (m_blocks, n_blocks); n inner.  The gains tile accumulates
        across the n sweep ("revisiting"); the last n step fuses the per-block
        argmax epilogue and emits this candidate block's (best_gain, best_idx)
        partial."""
        mi = pl.program_id(0)
        ni = pl.program_id(1)

        @pl.when(ni == 0)
        def _init():
            gains_ref[...] = jnp.zeros_like(gains_ref)

        dots = jax.lax.dot_general(
            x_ref[...],
            e_ref[...],
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (bn, bm) fp32 even for bf16 tiles
        d2 = sqx_ref[...] + sqe_ref[...] - 2.0 * dots
        dist = jnp.sqrt(jnp.maximum(d2, 0.0))
        contrib = jnp.maximum(madj_ref[...] - dist, 0.0)
        gains_ref[...] += jnp.sum(contrib, axis=0, keepdims=True)

        @pl.when(ni == pl.num_programs(1) - 1)
        def _epilogue():
            total = gains_ref[...] + pen_ref[...]  # (1, bm)
            best = jnp.max(total, axis=1, keepdims=True)  # (1, 1)
            pos = _first_hit(total, best)  # (1, 1) int32, lowest tie
            bg_ref[...] = best
            bi_ref[...] = mi * block_m + pos

    return kernel


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret")
)
def fl_gains_argmax_pallas(
    x: jax.Array,
    e: jax.Array,
    madj: jax.Array,
    sqx: jax.Array,
    sqe: jax.Array,
    penalty: jax.Array,
    *,
    block_n: int = 512,
    block_m: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused gains sweep + per-block argmax partials (device greedy engine).

    Args:
      x: (n, d) fp32/bf16, n % block_n == 0, d % 128 == 0.
      e: (m, d) candidates, m % block_m == 0, same dtype as x.
      madj: (n, 1) fp32 = d_max − cur_max (−1e30 on padded pool rows).
      sqx: (n, 1) fp32 squared norms of x.
      sqe: (1, m) fp32 squared norms of e.
      penalty: (1, m) fp32 — 0 for live candidates, −1e30 for columns that
        must not win (already-selected or padding).
    Returns:
      (gains (m,) fp32, best_g (m_blocks,) fp32, best_i (m_blocks,) int32):
      the full un-penalized gains vector (the device engine keeps it as its
      Minoux upper bounds between sweeps) plus each candidate block's top
      penalized gain and its global candidate index (lowest index on ties).
      The caller finalizes the winner with an O(m_blocks) argmax / top-k.
    """
    n, d = x.shape
    m = e.shape[0]
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    assert x.dtype == e.dtype, (x.dtype, e.dtype)
    n_blocks = n // block_n
    m_blocks = m // block_m
    grid = (m_blocks, n_blocks)
    gains, bg, bi = pl.pallas_call(
        _make_argmax_kernel(block_m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda mi, ni: (ni, 0)),
            pl.BlockSpec((block_m, d), lambda mi, ni: (mi, 0)),
            pl.BlockSpec((block_n, 1), lambda mi, ni: (ni, 0)),
            pl.BlockSpec((block_n, 1), lambda mi, ni: (ni, 0)),
            pl.BlockSpec((1, block_m), lambda mi, ni: (0, mi)),
            pl.BlockSpec((1, block_m), lambda mi, ni: (0, mi)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_m), lambda mi, ni: (0, mi)),
            pl.BlockSpec((1, 1), lambda mi, ni: (0, mi)),
            pl.BlockSpec((1, 1), lambda mi, ni: (0, mi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, m), jnp.float32),
            jax.ShapeDtypeStruct((1, m_blocks), jnp.float32),
            jax.ShapeDtypeStruct((1, m_blocks), jnp.int32),
        ],
        compiler_params=_TPU_PARAMS,
        interpret=interpret,
    )(
        x,
        e,
        madj.astype(jnp.float32),
        sqx.astype(jnp.float32),
        sqe.astype(jnp.float32),
        penalty.astype(jnp.float32),
    )
    return gains[0], bg[0], bi[0]
