"""Pallas TPU kernels: fused facility-location marginal gains (CRAIG hot-spot).

One greedy step of CRAIG (paper Alg. 1 line 3) evaluates, for every candidate
e, the marginal gain

    gain(e) = Σ_i relu( s_ie − cur_max_i ),     s_ie = d_max − ‖x_i − x_e‖

over the whole pool i ∈ V.  Done naively this materializes an (n, m)
similarity matrix in HBM per step.  ``fl_gains_pallas`` fuses

    pairwise-distance (MXU matmul x·eᵀ + rank-1 squared-norm terms)
      → similarity → subtract running max → relu → reduce over n

entirely in VMEM, tiled (block_n × block_m), accumulating the n-reduction
across grid steps into the (1, block_m) output tile.  Arithmetic intensity is
that of a matmul with a free epilogue — the MXU term dominates.

``fl_gains_argmax_pallas`` (DESIGN.md §2, §3.6) extends the same sweep with a
fused argmax epilogue for the device-resident greedy engine: the gains tile
accumulates in a VMEM scratch buffer instead of the output, and on the last
n-step each candidate block reduces itself to a single
``(best_gain, best_index)`` partial (max-reduce + first-hit index extraction —
no argmax primitive, same idiom as ``topk_sim``).  One kernel launch per
greedy round replaces the gains-materialize + separate argmax pair; the
host-side finalize is an O(m/block_m) reduction over the partials.
Already-selected candidates are excluded *inside* the epilogue via an
additive ``penalty`` row (−1e30 on chosen/padded columns), so no masked
(1, m) gains vector ever exists.

Inputs are pre-arranged by :mod:`repro.kernels.ops`:
  x      (n, d)   pool proxy features (fp32 or bf16), d padded to a lane
                  multiple
  e      (m, d)   candidate features (same dtype as x)
  madj   (n, 1)   d_max − cur_max_i   (similarity headroom per point, fp32)
  sqx    (n, 1)   ‖x_i‖²  (fp32)
  sqe    (1, m)   ‖x_e‖²  (fp32)
  penalty (1, m)  0 for live candidates, −1e30 for chosen/padded columns
                  (argmax variant only)
Outputs:
  gains  (1, m)   fp32                       (fl_gains_pallas)
  gains (1, m) + best_g (1, m_blocks) fp32 + best_i (1, m_blocks) int32
                                             (fl_gains_argmax_pallas)

TPU mapping notes (DESIGN.md §2): block shapes default to (512, 256) with the
full proxy dim d resident (d ≤ 8·128 after padding); all matmul dims are
multiples of 128 so the 128×128 MXU tiles are dense.  The n-grid axis is the
inner (fastest) axis so the output tile (or the scratch accumulator) stays
resident while the reduction accumulates ("revisiting" accumulation pattern).
Tiles may be bf16 (MXU-native) while distances, gains, and the running
accumulation stay fp32 (``preferred_element_type``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import pltpu, tpu_params

_TPU_PARAMS = tpu_params("parallel", "arbitrary")
_REPLAY_PARAMS = tpu_params("arbitrary", "arbitrary")

__all__ = ["fl_gains_pallas", "fl_gains_argmax_pallas", "fl_replay_pallas"]


def _first_hit(values: jax.Array, target: jax.Array) -> jax.Array:
    """Lowest column position where ``values`` equals per-row ``target``.

    values: (r, w); target: (r, 1).  Returns (r, 1) int32 positions — the
    no-argmax-primitive idiom shared with ``topk_sim`` (DESIGN.md §2).
    """
    w = values.shape[1]
    pos = jax.lax.broadcasted_iota(jnp.int32, values.shape, 1)
    return jnp.min(jnp.where(values == target, pos, w), axis=1, keepdims=True)


def _fl_gains_kernel(x_ref, e_ref, madj_ref, sqx_ref, sqe_ref, out_ref):
    """Grid = (m_blocks, n_blocks); n is the inner reduction axis."""
    ni = pl.program_id(1)

    @pl.when(ni == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]  # (bn, d)
    e = e_ref[...]  # (bm, d)
    # Squared distance via the MXU: ‖x−e‖² = ‖x‖² + ‖e‖² − 2 x·e
    dots = jax.lax.dot_general(
        x,
        e,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bn, bm)
    d2 = sqx_ref[...] + sqe_ref[...] - 2.0 * dots
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    # gain contribution: relu((d_max − cur_max) − dist)
    contrib = jnp.maximum(madj_ref[...] - dist, 0.0)  # (bn, bm)
    out_ref[...] += jnp.sum(contrib, axis=0, keepdims=True)  # (1, bm)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret")
)
def fl_gains_pallas(
    x: jax.Array,
    e: jax.Array,
    madj: jax.Array,
    sqx: jax.Array,
    sqe: jax.Array,
    *,
    block_n: int = 512,
    block_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Blocked fused FL gains. Shapes must already be block-aligned.

    Args:
      x: (n, d) fp32, n % block_n == 0, d % 128 == 0.
      e: (m, d) fp32, m % block_m == 0.
      madj: (n, 1) fp32 = d_max − cur_max.
      sqx: (n, 1) fp32 squared norms of x.
      sqe: (1, m) fp32 squared norms of e.
    Returns:
      (m,) fp32 gains.
    """
    n, d = x.shape
    m = e.shape[0]
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    grid = (m // block_m, n // block_n)
    out = pl.pallas_call(
        _fl_gains_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda mi, ni: (ni, 0)),
            pl.BlockSpec((block_m, d), lambda mi, ni: (mi, 0)),
            pl.BlockSpec((block_n, 1), lambda mi, ni: (ni, 0)),
            pl.BlockSpec((block_n, 1), lambda mi, ni: (ni, 0)),
            pl.BlockSpec((1, block_m), lambda mi, ni: (0, mi)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda mi, ni: (0, mi)),
        out_shape=jax.ShapeDtypeStruct((1, m), jnp.float32),
        compiler_params=_TPU_PARAMS,
        interpret=interpret,
    )(
        x.astype(jnp.float32),
        e.astype(jnp.float32),
        madj.astype(jnp.float32),
        sqx.astype(jnp.float32),
        sqe.astype(jnp.float32),
    )
    return out[0]


def _make_argmax_kernel(block_m: int):
    def kernel(
        x_ref, e_ref, madj_ref, sqx_ref, sqe_ref, pen_ref,
        gains_ref, bg_ref, bi_ref,
    ):
        """Grid = (m_blocks, n_blocks); n inner.  The gains tile accumulates
        across the n sweep ("revisiting"); the last n step fuses the per-block
        argmax epilogue and emits this candidate block's (best_gain, best_idx)
        partial."""
        mi = pl.program_id(0)
        ni = pl.program_id(1)

        @pl.when(ni == 0)
        def _init():
            gains_ref[...] = jnp.zeros_like(gains_ref)

        dots = jax.lax.dot_general(
            x_ref[...],
            e_ref[...],
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (bn, bm) fp32 even for bf16 tiles
        d2 = sqx_ref[...] + sqe_ref[...] - 2.0 * dots
        dist = jnp.sqrt(jnp.maximum(d2, 0.0))
        contrib = jnp.maximum(madj_ref[...] - dist, 0.0)
        gains_ref[...] += jnp.sum(contrib, axis=0, keepdims=True)

        @pl.when(ni == pl.num_programs(1) - 1)
        def _epilogue():
            total = gains_ref[...] + pen_ref[...]  # (1, bm)
            best = jnp.max(total, axis=1, keepdims=True)  # (1, 1)
            pos = _first_hit(total, best)  # (1, 1) int32, lowest tie
            bg_ref[...] = best
            bi_ref[...] = mi * block_m + pos

    return kernel


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret")
)
def fl_gains_argmax_pallas(
    x: jax.Array,
    e: jax.Array,
    madj: jax.Array,
    sqx: jax.Array,
    sqe: jax.Array,
    penalty: jax.Array,
    *,
    block_n: int = 512,
    block_m: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused gains sweep + per-block argmax partials (device greedy engine).

    Args:
      x: (n, d) fp32/bf16, n % block_n == 0, d % 128 == 0.
      e: (m, d) candidates, m % block_m == 0, same dtype as x.
      madj: (n, 1) fp32 = d_max − cur_max (−1e30 on padded pool rows).
      sqx: (n, 1) fp32 squared norms of x.
      sqe: (1, m) fp32 squared norms of e.
      penalty: (1, m) fp32 — 0 for live candidates, −1e30 for columns that
        must not win (already-selected or padding).
    Returns:
      (gains (m,) fp32, best_g (m_blocks,) fp32, best_i (m_blocks,) int32):
      the full un-penalized gains vector (the device engine keeps it as its
      Minoux upper bounds between sweeps) plus each candidate block's top
      penalized gain and its global candidate index (lowest index on ties).
      The caller finalizes the winner with an O(m_blocks) argmax / top-k.
    """
    n, d = x.shape
    m = e.shape[0]
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    assert x.dtype == e.dtype, (x.dtype, e.dtype)
    n_blocks = n // block_n
    m_blocks = m // block_m
    grid = (m_blocks, n_blocks)
    gains, bg, bi = pl.pallas_call(
        _make_argmax_kernel(block_m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda mi, ni: (ni, 0)),
            pl.BlockSpec((block_m, d), lambda mi, ni: (mi, 0)),
            pl.BlockSpec((block_n, 1), lambda mi, ni: (ni, 0)),
            pl.BlockSpec((block_n, 1), lambda mi, ni: (ni, 0)),
            pl.BlockSpec((1, block_m), lambda mi, ni: (0, mi)),
            pl.BlockSpec((1, block_m), lambda mi, ni: (0, mi)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_m), lambda mi, ni: (0, mi)),
            pl.BlockSpec((1, 1), lambda mi, ni: (0, mi)),
            pl.BlockSpec((1, 1), lambda mi, ni: (0, mi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, m), jnp.float32),
            jax.ShapeDtypeStruct((1, m_blocks), jnp.float32),
            jax.ShapeDtypeStruct((1, m_blocks), jnp.int32),
        ],
        compiler_params=_TPU_PARAMS,
        interpret=interpret,
    )(
        x,
        e,
        madj.astype(jnp.float32),
        sqx.astype(jnp.float32),
        sqe.astype(jnp.float32),
        penalty.astype(jnp.float32),
    )
    return gains[0], bg[0], bi[0]


def _replay_kernel(
    x_ref, e_ref, sqx_ref, sqe_ref, valid_ref, dm_ref, cur0_ref,
    gains_ref, cur_ref, bv_ref, bi_ref,
    cur_s, bv_s, bi_s,
):
    """Grid = (n_blocks, m_blocks); m (candidate order) is the inner axis.

    Each row block sweeps the ordered candidate blocks sequentially: the
    cover state ``cur`` and running per-row argmax ``(best_val, best_pos)``
    live in (block_n, 1) VMEM scratch across the inner sweep.  Within a
    block the candidates replay one column at a time (``fori_loop`` over
    the bm lanes — the greedy recurrence is inherently sequential), but the
    similarity tile itself comes from one MXU matmul.  Gains partials are
    written per (ni, mi) block — distinct output blocks, no revisiting —
    and the host sums the n_blocks partial rows.
    """
    mi = pl.program_id(1)
    bn = x_ref.shape[0]
    bm = e_ref.shape[0]

    @pl.when(mi == 0)
    def _init_row_state():
        cur_s[...] = cur0_ref[...]
        bv_s[...] = jnp.full((bn, 1), -1e30, jnp.float32)
        bi_s[...] = jnp.zeros((bn, 1), jnp.int32)

    dots = jax.lax.dot_general(
        x_ref[...],
        e_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bn, bm)
    d2 = sqx_ref[...] + sqe_ref[...] - 2.0 * dots
    s = dm_ref[...] - jnp.sqrt(jnp.maximum(d2, 0.0))
    # dead columns (padding / caller-masked) must neither gain nor cover
    s_cov = jnp.where(valid_ref[...] > 0.0, s, -1e30)

    col_pos = jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1)

    def step(t, carry):
        cur, gacc = carry
        hit = col_pos == t  # (1, bm) one-hot lane mask
        col = jnp.max(jnp.where(hit, s_cov, -1e30), axis=1, keepdims=True)
        g = jnp.sum(jnp.maximum(col - cur, 0.0))  # dead col → relu 0
        gacc = gacc + jnp.where(hit, g, 0.0)
        return jnp.maximum(cur, col), gacc

    cur_fin, gblk = jax.lax.fori_loop(
        0, bm, step, (cur_s[...], jnp.zeros((1, bm), jnp.float32))
    )
    cur_s[...] = cur_fin
    gains_ref[...] = gblk

    # per-row argmax over candidate columns (γ assignment): strict > keeps
    # the earlier block on ties; _first_hit keeps the lowest lane in-block —
    # together exactly jnp.argmax's lowest-index tie rule over the full list
    bval = jnp.max(s_cov, axis=1, keepdims=True)  # (bn, 1)
    bpos = _first_hit(s_cov, bval)
    upd = bval > bv_s[...]
    bv_new = jnp.where(upd, bval, bv_s[...])
    bi_new = jnp.where(upd, mi * bm + bpos, bi_s[...])
    bv_s[...] = bv_new
    bi_s[...] = bi_new
    cur_ref[...] = cur_fin
    bv_ref[...] = bv_new
    bi_ref[...] = bi_new


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret")
)
def fl_replay_pallas(
    x: jax.Array,
    e: jax.Array,
    sqx: jax.Array,
    sqe: jax.Array,
    valid: jax.Array,
    dm: jax.Array,
    cur0: jax.Array,
    *,
    block_n: int = 512,
    block_m: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Blocked sequential facility-location replay of an ordered candidate
    list (the streaming finalize sweep, DESIGN.md §10).

    Replays candidates ``e`` (rows, in selection order) against pool ``x``:
    gains[t] = Σ_i relu(s_it − max(cur0_i, max_{t'<t} s_it')), plus the
    final cover state and each pool row's best candidate (value, position)
    for γ assignment.  One MXU matmul per (block_n, block_m) tile replaces
    the per-candidate dense matvec of the naive replay.

    Args:
      x: (n, d) fp32 pool, n % block_n == 0, d % 128 == 0.
      e: (m, d) fp32 ordered candidates, m % block_m == 0.
      sqx: (n, 1) fp32 squared norms of x (pad rows: see cur0).
      sqe: (1, m) fp32 squared norms of e.
      valid: (1, m) fp32 — 1 for live candidate columns, 0 for padding
        (dead columns contribute no gain, no cover, never win assignment).
      dm: (1, 1) fp32 similarity offset (s = dm − dist).
      cur0: (n, 1) fp32 initial cover state; padded pool rows carry +1e30
        so they contribute 0 to every gain.
    Returns:
      (gains (n_blocks, m) fp32 partials — sum axis 0 for the totals,
       cur (n, 1) fp32, best_v (n, 1) fp32, best_i (n, 1) int32).
    """
    n, d = x.shape
    m = e.shape[0]
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    n_blocks = n // block_n
    m_blocks = m // block_m
    grid = (n_blocks, m_blocks)
    return pl.pallas_call(
        _replay_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda ni, mi: (ni, 0)),
            pl.BlockSpec((block_m, d), lambda ni, mi: (mi, 0)),
            pl.BlockSpec((block_n, 1), lambda ni, mi: (ni, 0)),
            pl.BlockSpec((1, block_m), lambda ni, mi: (0, mi)),
            pl.BlockSpec((1, block_m), lambda ni, mi: (0, mi)),
            pl.BlockSpec((1, 1), lambda ni, mi: (0, 0)),
            pl.BlockSpec((block_n, 1), lambda ni, mi: (ni, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_m), lambda ni, mi: (ni, mi)),
            pl.BlockSpec((block_n, 1), lambda ni, mi: (ni, 0)),
            pl.BlockSpec((block_n, 1), lambda ni, mi: (ni, 0)),
            pl.BlockSpec((block_n, 1), lambda ni, mi: (ni, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, m), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_n, 1), jnp.float32),  # cover state
            pltpu.VMEM((block_n, 1), jnp.float32),  # best value
            pltpu.VMEM((block_n, 1), jnp.int32),  # best position
        ],
        compiler_params=_REPLAY_PARAMS,
        interpret=interpret,
    )(
        x.astype(jnp.float32),
        e.astype(jnp.float32),
        sqx.astype(jnp.float32),
        sqe.astype(jnp.float32),
        valid.astype(jnp.float32),
        dm.astype(jnp.float32),
        cur0.astype(jnp.float32),
    )
