"""Pallas TPU kernel: blocked pairwise L2 distance matrix (CRAIG matrix mode).

Computes D[i, j] = ‖x_i − y_j‖ for x (n, d), y (m, d), tiled so each
(block_n × block_m) output tile is produced from one MXU matmul plus rank-1
squared-norm corrections, with the proxy dim d resident in VMEM.

Used by the `matrix` selection engine when the per-shard pool is small enough
to hold (n, m) in HBM (per-class selection typically is); the matrix-free
`fl_gains` kernel covers the large-pool regime.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import tpu_params

_TPU_PARAMS = tpu_params("parallel", "parallel")

__all__ = ["pairwise_l2_pallas"]


def _pairwise_kernel(x_ref, y_ref, sqx_ref, sqy_ref, out_ref):
    dots = jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    d2 = sqx_ref[...] + sqy_ref[...] - 2.0 * dots
    out_ref[...] = jnp.sqrt(jnp.maximum(d2, 0.0))


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret"))
def pairwise_l2_pallas(
    x: jax.Array,
    y: jax.Array,
    *,
    block_n: int = 256,
    block_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Blocked pairwise distances. n, m must be block-aligned; d % 128 == 0.

    Returns (n, m) fp32 distances.
    """
    n, d = x.shape
    m = y.shape[0]
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    sqx = jnp.sum(x * x, axis=1, keepdims=True)  # (n, 1)
    sqy = jnp.sum(y * y, axis=1, keepdims=True).T  # (1, m)
    grid = (n // block_n, m // block_m)
    return pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda ni, mi: (ni, 0)),
            pl.BlockSpec((block_m, d), lambda ni, mi: (mi, 0)),
            pl.BlockSpec((block_n, 1), lambda ni, mi: (ni, 0)),
            pl.BlockSpec((1, block_m), lambda ni, mi: (0, mi)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda ni, mi: (ni, mi)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        compiler_params=_TPU_PARAMS,
        interpret=interpret,
    )(x, y, sqx, sqy)
