"""Pallas TPU kernel: blockwise top-k similarity neighbor builder (sparse CRAIG).

The sparse selection engine (DESIGN.md §3.5) replaces the dense (n, n)
similarity structure with a k-nearest-neighbor graph: for every point i it
keeps only the k largest similarities s_ij = d_max − ‖x_i − x_j‖ together
with their column indices.  This kernel builds that graph by streaming
(block_n × block_m) similarity tiles — the same MXU matmul + rank-1
squared-norm epilogue as ``pairwise_l2`` / ``fl_gains`` — and folding each
tile into a per-row running top-k that stays resident in the output tiles
across the column sweep ("revisiting" accumulation, fl_gains-style).  The
dense (n, n) matrix is never materialized: peak memory is
O(block_n · block_m) VMEM per tile plus the O(n · k) output.

The in-tile merge is selection-sort shaped: k unrolled iterations, each a
max-reduce over the carry row and the tile row, a first-hit index extraction
(broadcasted_iota + min-reduce — no 1D iota, no argmax primitive), and a
mask-out of the winner.  All ops are plain VPU compares/reductions, so the
kernel lowers on Mosaic without lax.top_k/sort support; cost per tile is
O(k · block_n · (k + block_m)), small next to the MXU term for k ≲ 128.

Inputs are pre-arranged by :mod:`repro.kernels.ops`:
  x      (n, d)   row-block features (fp32), d padded to a lane multiple
  y      (m, d)   column-block features (= x padded; m ≥ n)
  sqx    (n, 1)   ‖x_i‖²
  sqy    (1, m)   ‖y_j‖²; padded columns carry +1e30 so their similarity is
                  ≈ −1e15 and they never enter a top-k (requires k ≤ n)
  dmax   (1, 1)   similarity offset: s = dmax − dist ≥ 0 for real columns
Outputs:
  vals   (n, k)   fp32 top-k similarities per row, sorted descending
  idx    (n, k)   int32 column indices aligned with ``vals``
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import tpu_params

_TPU_PARAMS = tpu_params("parallel", "arbitrary")

__all__ = ["topk_sim_pallas"]

_NEG = -1e30  # top-k init / mask-out value (−inf is unsafe on some backends)


def _first_hit(values: jax.Array, target: jax.Array) -> jax.Array:
    """Lowest column position where ``values`` equals per-row ``target``.

    values: (bn, w); target: (bn, 1).  Returns (bn, 1) int32 positions.
    """
    w = values.shape[1]
    pos = jax.lax.broadcasted_iota(jnp.int32, values.shape, 1)
    return jnp.min(jnp.where(values == target, pos, w), axis=1, keepdims=True)


def _make_topk_kernel(k: int, block_m: int):
    def kernel(x_ref, y_ref, sqx_ref, sqy_ref, dmax_ref, vals_ref, idx_ref):
        mi = pl.program_id(1)

        @pl.when(mi == 0)
        def _init():
            vals_ref[...] = jnp.full_like(vals_ref, _NEG)
            idx_ref[...] = jnp.zeros_like(idx_ref)

        dots = jax.lax.dot_general(
            x_ref[...],
            y_ref[...],
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (bn, bm)
        d2 = sqx_ref[...] + sqy_ref[...] - 2.0 * dots
        tile_v = dmax_ref[...] - jnp.sqrt(jnp.maximum(d2, 0.0))
        tile_i = mi * block_m + jax.lax.broadcasted_iota(
            jnp.int32, tile_v.shape, 1
        )

        carry_v = vals_ref[...]  # (bn, k) — previous blocks' top-k
        carry_i = idx_ref[...]
        # Selection-sort merge: carry wins ties (its entries come from
        # earlier column blocks, i.e. lower indices — matches lax.top_k's
        # stable index-ascending tie-break).
        for t in range(k):
            c_best = jnp.max(carry_v, axis=1, keepdims=True)  # (bn, 1)
            t_best = jnp.max(tile_v, axis=1, keepdims=True)
            use_carry = c_best >= t_best
            c_pos = _first_hit(carry_v, c_best)
            t_pos = _first_hit(tile_v, t_best)
            c_cols = jax.lax.broadcasted_iota(jnp.int32, carry_v.shape, 1)
            t_cols = jax.lax.broadcasted_iota(jnp.int32, tile_v.shape, 1)
            c_val = jnp.sum(
                jnp.where(c_cols == c_pos, carry_i, 0), axis=1, keepdims=True
            )
            t_val = jnp.sum(
                jnp.where(t_cols == t_pos, tile_i, 0), axis=1, keepdims=True
            )
            vals_ref[:, t : t + 1] = jnp.where(use_carry, c_best, t_best)
            idx_ref[:, t : t + 1] = jnp.where(use_carry, c_val, t_val)
            # Knock the winner out of its source array.
            carry_v = jnp.where(
                use_carry & (c_cols == c_pos), _NEG, carry_v
            )
            tile_v = jnp.where(
                (~use_carry) & (t_cols == t_pos), _NEG, tile_v
            )

    return kernel


@functools.partial(
    jax.jit, static_argnames=("k", "block_n", "block_m", "interpret")
)
def topk_sim_pallas(
    x: jax.Array,
    y: jax.Array,
    sqx: jax.Array,
    sqy: jax.Array,
    dmax: jax.Array,
    *,
    k: int,
    block_n: int = 256,
    block_m: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Blocked top-k similarity rows.  Shapes must already be block-aligned.

    Args:
      x: (n, d) fp32, n % block_n == 0, d % 128 == 0.
      y: (m, d) fp32, m % block_m == 0 (the column/candidate features).
      sqx: (n, 1) fp32 squared norms of x.
      sqy: (1, m) fp32 squared norms of y (+1e30 on padded columns).
      dmax: (1, 1) fp32 similarity offset.
      k: neighbors kept per row (static; k ≤ #valid columns).
    Returns:
      vals (n, k) fp32 descending, idx (n, k) int32.
    """
    n, d = x.shape
    m = y.shape[0]
    assert n % block_n == 0 and m % block_m == 0, (n, m, block_n, block_m)
    grid = (n // block_n, m // block_m)
    vals, idx = pl.pallas_call(
        _make_topk_kernel(k, block_m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda ni, mi: (ni, 0)),
            pl.BlockSpec((block_m, d), lambda ni, mi: (mi, 0)),
            pl.BlockSpec((block_n, 1), lambda ni, mi: (ni, 0)),
            pl.BlockSpec((1, block_m), lambda ni, mi: (0, mi)),
            pl.BlockSpec((1, 1), lambda ni, mi: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, k), lambda ni, mi: (ni, 0)),
            pl.BlockSpec((block_n, k), lambda ni, mi: (ni, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), jnp.float32),
            jax.ShapeDtypeStruct((n, k), jnp.int32),
        ],
        compiler_params=_TPU_PARAMS,
        interpret=interpret,
    )(
        x.astype(jnp.float32),
        y.astype(jnp.float32),
        sqx.astype(jnp.float32),
        sqy.astype(jnp.float32),
        dmax.astype(jnp.float32),
    )
    return vals, idx
