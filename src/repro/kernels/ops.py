"""Public jit'd wrappers for the Pallas kernels.

Handles shape padding to block/lane multiples, backend selection (interpret
mode on CPU so the kernels are CI-testable without a TPU), and the
feature-space bookkeeping CRAIG's greedy loop needs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ce_proxy as _ce
from repro.kernels import fl_gains as _fl
from repro.kernels import pairwise_l2 as _pw
from repro.kernels import topk_sim as _tk

__all__ = [
    "fl_gains",
    "fl_gains_argmax",
    "fl_replay",
    "pairwise_l2",
    "ce_proxy",
    "topk_sim",
    "interpret_default",
]

_LANE = 128


def interpret_default() -> bool:
    """Pallas interpret mode unless running on a real TPU."""
    return jax.default_backend() != "tpu"


def _pad_dim(a: jax.Array, axis: int, mult: int, value: float = 0.0) -> jax.Array:
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret")
)
def fl_gains(
    x: jax.Array,
    e: jax.Array,
    cur_max: jax.Array,
    sqx: jax.Array,
    sqe: jax.Array,
    d_max: jax.Array,
    *,
    block_n: int = 512,
    block_m: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Marginal FL gains of candidates ``e`` against pool ``x``.

    gains[c] = Σ_i relu((d_max − ‖x_i − e_c‖) − cur_max_i).

    Padding: pool rows are padded with duplicates of row 0 but their
    contribution is cancelled by setting padded madj = −inf → relu 0.
    Candidate padding produces garbage gains that the caller slices off.
    """
    if interpret is None:
        interpret = interpret_default()
    n, d = x.shape
    m = e.shape[0]
    bn = min(block_n, max(_LANE, 1 << (n - 1).bit_length()))
    bm = min(block_m, max(_LANE, 1 << (m - 1).bit_length()))
    xp = _pad_dim(_pad_dim(x, 0, bn), 1, _LANE)
    ep = _pad_dim(_pad_dim(e, 0, bm), 1, _LANE)
    madj = d_max - cur_max.astype(jnp.float32)
    madj = _pad_dim(madj.reshape(n, 1), 0, bn, value=-1e30)
    sqxp = _pad_dim(sqx.astype(jnp.float32).reshape(n, 1), 0, bn)
    sqep = _pad_dim(sqe.astype(jnp.float32).reshape(1, m), 1, bm)
    out = _fl.fl_gains_pallas(
        xp, ep, madj, sqxp, sqep, block_n=bn, block_m=bm, interpret=interpret
    )
    return out[:m]


@functools.partial(
    jax.jit,
    static_argnames=("block_n", "block_m", "tile_dtype", "interpret"),
)
def fl_gains_argmax(
    x: jax.Array,
    e: jax.Array,
    cur_max: jax.Array,
    sqx: jax.Array,
    sqe: jax.Array,
    d_max: jax.Array,
    chosen_e: jax.Array,
    *,
    block_n: int = 512,
    block_m: int = 256,
    tile_dtype: str = "float32",
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """One fused greedy round: gains sweep + per-block argmax partials.

    For the device-resident greedy engine (DESIGN.md §3.6): a single kernel
    launch computes every candidate's marginal gain *and* reduces each
    candidate block to a ``(best_gain, best_index)`` partial, with
    already-selected candidates excluded inside the kernel.  The caller
    finalizes the winner over the O(m/block_m) partials; the full gains
    vector rides along as the engine's Minoux upper bounds between sweeps
    (block-greedy mode).

    Padding contract (DESIGN.md §2): pool rows pad with madj = −1e30 → relu 0
    (inert through the reduction); candidate padding and ``chosen_e`` columns
    carry an additive −1e30 penalty so they can only win a block in which
    every candidate is dead — such blocks report best_gain ≤ −1e29 and the
    caller must ignore them (real gains are always ≥ 0).

    Args:
      x: (n, d) pool features.
      e: (m, d) candidate features.
      cur_max: (n,) fp32 running cover state max_{j∈S} s_ij.
      sqx: (n,) fp32 squared norms of x.
      sqe: (m,) fp32 squared norms of e.
      d_max: traced fp32 scalar similarity offset.
      chosen_e: (m,) bool — candidates to exclude (already selected).
      tile_dtype: 'float32' | 'bfloat16' — dtype of the feature tiles fed to
        the MXU; distances/gains always accumulate in fp32.
    Returns:
      (gains (m,) fp32, part_g (m_blocks,) fp32, part_i (m_blocks,) int32) —
      every candidate's un-penalized gain, plus per-block best penalized
      gain and its candidate index (lowest index on ties).
    """
    if interpret is None:
        interpret = interpret_default()
    td = jnp.dtype(tile_dtype)
    if td not in (jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16)):
        raise ValueError(f"unsupported tile_dtype {tile_dtype!r}")
    n, d = x.shape
    m = e.shape[0]
    bn = min(block_n, max(_LANE, 1 << (n - 1).bit_length()))
    bm = min(block_m, max(_LANE, 1 << (m - 1).bit_length()))
    xp = _pad_dim(_pad_dim(x.astype(td), 0, bn), 1, _LANE)
    ep = _pad_dim(_pad_dim(e.astype(td), 0, bm), 1, _LANE)
    madj = d_max - cur_max.astype(jnp.float32)
    madj = _pad_dim(madj.reshape(n, 1), 0, bn, value=-1e30)
    sqxp = _pad_dim(sqx.astype(jnp.float32).reshape(n, 1), 0, bn)
    sqep = _pad_dim(sqe.astype(jnp.float32).reshape(1, m), 1, bm)
    pen = jnp.where(chosen_e, -1e30, 0.0).astype(jnp.float32)
    pen = _pad_dim(pen.reshape(1, m), 1, bm, value=-1e30)
    gains, part_g, part_i = _fl.fl_gains_argmax_pallas(
        xp, ep, madj, sqxp, sqep, pen,
        block_n=bn, block_m=bm, interpret=interpret,
    )
    return gains[:m], part_g, part_i


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret")
)
def fl_replay(
    x: jax.Array,
    e: jax.Array,
    valid: jax.Array,
    cur0: jax.Array,
    d_max: jax.Array,
    *,
    block_n: int = 512,
    block_m: int = 128,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Sequential FL replay of an ordered candidate list (streaming finalize).

    gains[t] = Σ_i relu(s_it − max(cur0_i, max_{t'<t} s_it')) with
    s_it = d_max − ‖x_i − e_t‖, i.e. the marginal-gain sequence a greedy
    run would record if it accepted candidates in exactly row order of
    ``e``.  Also returns the final cover state and each pool row's best
    candidate (value, row position of ``e``) for γ assignment —
    lowest-position on ties, matching ``jnp.argmax``.

    Padding: pool rows pad with cur0 = +1e30 (inert: relu 0 in every gain,
    garbage best sliced off); candidate rows pad with valid = 0 (no gain,
    no cover, can never win assignment).

    Args:
      x: (n, d) pool features.
      e: (m, d) candidates, rows in selection order.
      valid: (m,) bool — False masks a candidate out entirely.
      cur0: (n,) fp32 initial cover state (zeros for a cold replay).
      d_max: traced fp32 scalar similarity offset.
    Returns:
      (gains (m,) fp32, cur (n,) fp32, best_v (n,) fp32, best_i (n,) int32).
    """
    if interpret is None:
        interpret = interpret_default()
    n, d = x.shape
    m = e.shape[0]
    x = x.astype(jnp.float32)
    e = e.astype(jnp.float32)
    sqx = jnp.sum(x * x, axis=1)
    sqe = jnp.sum(e * e, axis=1)
    bn = min(block_n, max(8, 1 << (n - 1).bit_length()))
    bm = min(block_m, max(_LANE, 1 << max(m - 1, 0).bit_length()))
    xp = _pad_dim(_pad_dim(x, 0, bn), 1, _LANE)
    ep = _pad_dim(_pad_dim(e, 0, bm), 1, _LANE)
    sqxp = _pad_dim(sqx.reshape(n, 1), 0, bn)
    sqep = _pad_dim(sqe.reshape(1, m), 1, bm)
    vp = _pad_dim(
        valid.astype(jnp.float32).reshape(1, m), 1, bm, value=0.0
    )
    curp = _pad_dim(
        cur0.astype(jnp.float32).reshape(n, 1), 0, bn, value=1e30
    )
    dm = jnp.asarray(d_max, jnp.float32).reshape(1, 1)
    gains, cur, bv, bi = _fl.fl_replay_pallas(
        xp, ep, sqxp, sqep, vp, dm, curp,
        block_n=bn, block_m=bm, interpret=interpret,
    )
    return (
        jnp.sum(gains, axis=0)[:m],
        cur[:n, 0],
        bv[:n, 0],
        bi[:n, 0],
    )


@functools.partial(
    jax.jit, static_argnames=("k", "block_n", "block_m", "interpret")
)
def topk_sim(
    x: jax.Array,
    k: int,
    d_max: jax.Array | None = None,
    *,
    block_n: int = 256,
    block_m: int = 256,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Top-k similarity graph rows of the pool against itself.

    Returns (vals (n, k) fp32 descending, idx (n, k) int32) where
    vals[i, t] = d_max − ‖x_i − x_{idx[i, t]}‖ over the k most similar
    columns (self included: idx[i, 0] == i).  O(n·k) output memory; the
    dense (n, n) similarity matrix is never materialized.

    Padding: pool rows pad with zeros and are sliced off; column padding
    carries sqy = +1e30 so padded similarities (≈ −1e15) never beat a real
    candidate — sound because k ≤ n and real similarities are ≥ 0.

    Args:
      x: (n, d) features.
      k: neighbors per row (static); clamped to n by the caller.
      d_max: similarity offset (traced scalar).  Defaults to the
        2·max‖x‖ + ε upper bound on the pairwise distance (triangle
        inequality), the same convention as ``greedy_fl_features``.
    """
    if interpret is None:
        interpret = interpret_default()
    n, d = x.shape
    assert 1 <= k <= n, (k, n)
    x = x.astype(jnp.float32)
    sq = jnp.sum(x * x, axis=1)
    if d_max is None:
        d_max = 2.0 * jnp.sqrt(jnp.max(sq)) + 1e-6
    bn = min(block_n, max(8, 1 << (n - 1).bit_length()))
    bm = min(block_m, max(_LANE, 1 << (n - 1).bit_length()))
    xp = _pad_dim(_pad_dim(x, 0, bn), 1, _LANE)
    yp = _pad_dim(_pad_dim(x, 0, bm), 1, _LANE)
    sqxp = _pad_dim(sq.reshape(n, 1), 0, bn)
    sqyp = _pad_dim(sq.reshape(1, n), 1, bm, value=1e30)
    dm = jnp.asarray(d_max, jnp.float32).reshape(1, 1)
    vals, idx = _tk.topk_sim_pallas(
        xp, yp, sqxp, sqyp, dm, k=k, block_n=bn, block_m=bm,
        interpret=interpret,
    )
    return vals[:n], idx[:n]


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "interpret")
)
def pairwise_l2(
    x: jax.Array,
    y: jax.Array,
    *,
    block_n: int = 256,
    block_m: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """(n, m) pairwise L2 distances via the blocked Pallas kernel."""
    if interpret is None:
        interpret = interpret_default()
    n = x.shape[0]
    m = y.shape[0]
    bn = min(block_n, max(8, 1 << (n - 1).bit_length()))
    bm = min(block_m, max(_LANE, 1 << (m - 1).bit_length()))
    xp = _pad_dim(_pad_dim(x, 0, bn), 1, _LANE)
    yp = _pad_dim(_pad_dim(y, 0, bm), 1, _LANE)
    out = _pw.pairwise_l2_pallas(
        xp, yp, block_n=bn, block_m=bm, interpret=interpret
    )
    return out[:n, :m]


@functools.partial(
    jax.jit,
    static_argnames=("block_t", "block_v", "interpret", "valid_v",
                     "compute_dtype"),
)
def ce_proxy(
    hidden: jax.Array,
    unembed: jax.Array,
    labels: jax.Array,
    *,
    block_t: int = 128,
    block_v: int = 512,
    interpret: bool | None = None,
    valid_v: int | None = None,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Fused per-token CRAIG proxy (softmax(hW) − y) @ Wᵀ → (T, D) fp32.

    Vocab padding is exact: V is zero-padded up to a ``block_v`` multiple
    and the padded columns (plus any caller-declared pad past ``valid_v``)
    are −∞-masked inside the kernel — the same padded-vocab bias
    ``core.proxy.lm_unembed_input_proxy`` applies, so the two proxy paths
    agree on vocab-padded configs.  ``compute_dtype=bf16`` runs the MXU
    matmuls in bf16 with fp32 accumulation (softmax state stays fp32).
    """
    if interpret is None:
        interpret = interpret_default()
    T, D = hidden.shape
    V = unembed.shape[1]
    vv = V if valid_v is None else valid_v
    bv = min(block_v, max(8, 1 << (V - 1).bit_length()))
    bt = min(block_t, max(8, 1 << (T - 1).bit_length()))
    hp = _pad_dim(_pad_dim(hidden, 0, bt), 1, _LANE)
    wp = _pad_dim(_pad_dim(unembed, 0, _LANE), 1, bv)
    lp = _pad_dim(labels.reshape(T), 0, bt)
    out = _ce.ce_proxy_pallas(
        hp, wp, lp, block_t=bt, block_v=bv, interpret=interpret,
        # mask everything past the real vocab, incl. the block padding,
        # unless nothing was padded at all
        valid_v=None if vv == wp.shape[1] else vv,
        compute_dtype=compute_dtype,
    )
    return out[:T, :D]
