"""Pallas TPU kernels for CRAIG hot-spots (validated via interpret mode)."""
from repro.kernels import ops, ref
from repro.kernels.ops import ce_proxy, fl_gains, pairwise_l2, topk_sim

__all__ = ["ops", "ref", "ce_proxy", "fl_gains", "pairwise_l2", "topk_sim"]
