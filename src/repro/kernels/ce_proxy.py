"""Pallas TPU kernel: fused CRAIG gradient-proxy for token streams.

Computes, for a chunk of T tokens with hidden states h_t ∈ R^d, labels y_t and
unembedding W ∈ R^{d×V}, the gradient of per-token CE w.r.t. the unembedding
input:

    g_t = (softmax(h_t W) − onehot(y_t)) @ Wᵀ      ∈ R^d

without ever materializing the (T, V) logits/softmax: the vocab axis is
blocked and reduced online flash-style.  Per vocab block v:

    z = h W_v                        (MXU, (bt, bv))
    m' = max(m, rowmax(z)); c = exp(m − m')
    l  = l·c + rowsum(exp(z − m'))
    acc  = acc·c + exp(z − m') @ W_vᵀ           (MXU)
    accy += onehot_v(y) @ W_vᵀ  (label column, unscaled)

final:  g = acc / l − accy.

This is the paper's §3.4 "gradient of the loss w.r.t. the input to the last
layer" (Eq. 16) for LMs (DESIGN.md §2): the only extra work on top of a
forward pass, fused so CRAIG's proxy extraction is bandwidth-, not
memory-capacity-, limited even at V = 256k.

Grid = (t_blocks, v_blocks), v inner; running (m, l, acc, accy) live in VMEM
scratch across the v sweep of each t block.

Vocab padding (``valid_v``): configs whose unembedding is padded to a tile
multiple (V_padded > vocab_size) mask the padded logit columns to −∞ inside
the kernel — the same padded-vocab bias ``lm_unembed_input_proxy`` applies —
so the two proxy paths agree bit-for-bit on vocab-padded configs.

Mixed precision (``compute_dtype``): the two MXU matmuls per block (h·W_v and
p·W_vᵀ / onehot·W_vᵀ) run in ``compute_dtype`` (bf16 on the production select
path) with fp32 accumulation via ``preferred_element_type``; the online
softmax state (m, l) and both accumulators stay fp32 — mirroring the
``lm_unembed_input_proxy`` contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import pltpu, tpu_params

_TPU_PARAMS = tpu_params("parallel", "arbitrary")

__all__ = ["ce_proxy_pallas"]

_NEG_INF = -1e30


def _ce_proxy_kernel(
    h_ref, w_ref, y_ref, out_ref, m_scr, l_scr, acc_scr, accy_scr,
    *, block_v, valid_v, compute_dtype
):
    vi = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        accy_scr[...] = jnp.zeros_like(accy_scr)

    h = h_ref[...]  # (bt, d) in compute_dtype
    w = w_ref[...]  # (d, bv) in compute_dtype
    z = jax.lax.dot_general(
        h, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bt, bv) fp32
    cols = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)  # (bt, bv) local
    if valid_v is not None:
        # padded-vocab bias (lm_unembed_input_proxy's pad_bias): columns
        # past the real vocab get −∞ logits → zero probability mass
        z = jnp.where(cols + vi * block_v < valid_v, z, _NEG_INF)

    m_prev = m_scr[...]  # (bt, 1)
    m_new = jnp.maximum(m_prev, jnp.max(z, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)  # (bt, 1)
    p = jnp.exp(z - m_new)  # (bt, bv) unnormalized, fp32
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    # acc ← acc·c + p @ Wᵀ  (MXU matmul in compute_dtype, fp32 accumulate)
    pw = jax.lax.dot_general(
        p.astype(compute_dtype), w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bt, d)
    acc_scr[...] = acc_scr[...] * corr + pw
    m_scr[...] = m_new

    # Label columns: onehot within this vocab block.
    y = y_ref[...]  # (bt, 1) int32 global vocab ids
    local = y - vi * block_v  # (bt, 1)
    onehot = (cols == local).astype(compute_dtype)  # rows w/ label elsewhere: 0
    yw = jax.lax.dot_general(
        onehot, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    accy_scr[...] += yw

    @pl.when(vi == nv - 1)
    def _finalize():
        out_ref[...] = acc_scr[...] / l_scr[...] - accy_scr[...]


@functools.partial(
    jax.jit,
    static_argnames=("block_t", "block_v", "interpret", "valid_v",
                     "compute_dtype"),
)
def ce_proxy_pallas(
    hidden: jax.Array,
    unembed: jax.Array,
    labels: jax.Array,
    *,
    block_t: int = 128,
    block_v: int = 512,
    interpret: bool = False,
    valid_v: int | None = None,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Fused (softmax(hW) − onehot(y)) @ Wᵀ over vocab blocks.

    Args:
      hidden: (T, D), T % block_t == 0, D % 128 == 0.
      unembed: (D, V), V % block_v == 0.
      labels: (T,) int32 in [0, valid_v or V).
      valid_v: real vocab size when V is tile-padded (1 ≤ valid_v ≤ V);
        padded columns are −∞-masked in-kernel, matching
        ``lm_unembed_input_proxy``'s pad bias.  None means all V columns
        are real.
      compute_dtype: dtype of the MXU matmuls (fp32 accumulation; softmax
        state stays fp32) — bf16 on the production select path.
    Returns:
      (T, D) fp32 per-token proxy gradients.
    """
    T, D = hidden.shape
    V = unembed.shape[1]
    assert T % block_t == 0 and V % block_v == 0, (T, V, block_t, block_v)
    if valid_v is not None and not 1 <= valid_v <= V:
        raise ValueError(f"valid_v={valid_v} outside [1, V={V}]")
    grid = (T // block_t, V // block_v)
    kernel = functools.partial(
        _ce_proxy_kernel, block_v=block_v, valid_v=valid_v,
        compute_dtype=compute_dtype,
    )
    scratch_shapes = [
        pltpu.VMEM((block_t, 1), jnp.float32),  # running max m
        pltpu.VMEM((block_t, 1), jnp.float32),  # running denom l
        pltpu.VMEM((block_t, D), jnp.float32),  # softmax@Wᵀ accumulator
        pltpu.VMEM((block_t, D), jnp.float32),  # label-column accumulator
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, D), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((D, block_v), lambda ti, vi: (0, vi)),
            pl.BlockSpec((block_t, 1), lambda ti, vi: (ti, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, D), lambda ti, vi: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), jnp.float32),
        scratch_shapes=scratch_shapes,
        compiler_params=_TPU_PARAMS,
        interpret=interpret,
    )(
        hidden.astype(compute_dtype),
        unembed.astype(compute_dtype),
        labels.astype(jnp.int32).reshape(T, 1),
    )
