"""Pallas TPU kernel: fused CRAIG gradient-proxy for token streams.

Computes, for a chunk of T tokens with hidden states h_t ∈ R^d, labels y_t and
unembedding W ∈ R^{d×V}, the gradient of per-token CE w.r.t. the unembedding
input:

    g_t = (softmax(h_t W) − onehot(y_t)) @ Wᵀ      ∈ R^d

without ever materializing the (T, V) logits/softmax: the vocab axis is
blocked and reduced online flash-style.  Per vocab block v:

    z = h W_v                        (MXU, (bt, bv))
    m' = max(m, rowmax(z)); c = exp(m − m')
    l  = l·c + rowsum(exp(z − m'))
    acc  = acc·c + exp(z − m') @ W_vᵀ           (MXU)
    accy += onehot_v(y) @ W_vᵀ  (label column, unscaled)

final:  g = acc / l − accy.

This is the paper's §3.4 "gradient of the loss w.r.t. the input to the last
layer" (Eq. 16) for LMs (DESIGN.md §2): the only extra work on top of a
forward pass, fused so CRAIG's proxy extraction is bandwidth-, not
memory-capacity-, limited even at V = 256k.

Grid = (t_blocks, v_blocks), v inner; running (m, l, acc, accy) live in VMEM
scratch across the v sweep of each t block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import pltpu, tpu_params

_TPU_PARAMS = tpu_params("parallel", "arbitrary")

__all__ = ["ce_proxy_pallas"]

_NEG_INF = -1e30


def _ce_proxy_kernel(
    h_ref, w_ref, y_ref, out_ref, m_scr, l_scr, acc_scr, accy_scr, *, block_v
):
    vi = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        accy_scr[...] = jnp.zeros_like(accy_scr)

    h = h_ref[...]  # (bt, d)
    w = w_ref[...]  # (d, bv)
    z = jax.lax.dot_general(
        h, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bt, bv)

    m_prev = m_scr[...]  # (bt, 1)
    m_new = jnp.maximum(m_prev, jnp.max(z, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)  # (bt, 1)
    p = jnp.exp(z - m_new)  # (bt, bv) unnormalized
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    # acc ← acc·c + p @ Wᵀ
    pw = jax.lax.dot_general(
        p, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bt, d)
    acc_scr[...] = acc_scr[...] * corr + pw
    m_scr[...] = m_new

    # Label columns: onehot within this vocab block.
    y = y_ref[...]  # (bt, 1) int32 global vocab ids
    local = y - vi * block_v  # (bt, 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)  # (bt, bv)
    onehot = (cols == local).astype(jnp.float32)  # rows w/ label elsewhere: 0
    yw = jax.lax.dot_general(
        onehot, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    accy_scr[...] += yw

    @pl.when(vi == nv - 1)
    def _finalize():
        out_ref[...] = acc_scr[...] / l_scr[...] - accy_scr[...]


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_v", "interpret")
)
def ce_proxy_pallas(
    hidden: jax.Array,
    unembed: jax.Array,
    labels: jax.Array,
    *,
    block_t: int = 128,
    block_v: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused (softmax(hW) − onehot(y)) @ Wᵀ over vocab blocks.

    Args:
      hidden: (T, D), T % block_t == 0, D % 128 == 0.
      unembed: (D, V), V % block_v == 0.
      labels: (T,) int32 in [0, V).
    Returns:
      (T, D) fp32 per-token proxy gradients.
    """
    T, D = hidden.shape
    V = unembed.shape[1]
    assert T % block_t == 0 and V % block_v == 0, (T, V, block_t, block_v)
    grid = (T // block_t, V // block_v)
    kernel = functools.partial(_ce_proxy_kernel, block_v=block_v)
    scratch_shapes = [
        pltpu.VMEM((block_t, 1), jnp.float32),  # running max m
        pltpu.VMEM((block_t, 1), jnp.float32),  # running denom l
        pltpu.VMEM((block_t, D), jnp.float32),  # softmax@Wᵀ accumulator
        pltpu.VMEM((block_t, D), jnp.float32),  # label-column accumulator
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, D), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((D, block_v), lambda ti, vi: (0, vi)),
            pl.BlockSpec((block_t, 1), lambda ti, vi: (ti, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, D), lambda ti, vi: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), jnp.float32),
        scratch_shapes=scratch_shapes,
        compiler_params=_TPU_PARAMS,
        interpret=interpret,
    )(
        hidden.astype(jnp.float32),
        unembed.astype(jnp.float32),
        labels.astype(jnp.int32).reshape(T, 1),
    )
