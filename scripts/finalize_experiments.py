"""Regenerate EXPERIMENTS.md §Roofline tables from the artifact dirs.

Rewrites everything between the ``<!-- ROOFLINE_TABLE -->`` markers in
EXPERIMENTS.md from ``artifacts/dryrun`` (optimized) and
``artifacts/dryrun_baseline`` (baseline).  Run after a dry-run sweep:

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python scripts/finalize_experiments.py
"""
from __future__ import annotations

import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import roofline  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")
OPT = os.path.join(ROOT, "artifacts", "dryrun")
BASE = os.path.join(ROOT, "artifacts", "dryrun_baseline")


def main() -> None:
    cells = roofline.analyze_all(OPT, "16x16")
    table = roofline.to_markdown(cells)
    n_probe = sum(1 for c in cells if c.extrapolated)
    caption = (
        f"\n*{len(cells)} cells ({n_probe} probe-extrapolated); optimized "
        "system (post-§Perf). Baseline tables: "
        "`python -m repro.roofline --out artifacts/dryrun_baseline "
        "--markdown`.*\n"
    )
    compare = roofline.compare_markdown(BASE, OPT, "16x16")
    notes = """

**Reading the comparison:**

* `decode_32k`: **4.7-65x** on the dominant term (GQA-repeat fix + TP-only
  serve params); every cell lands memory-bound at the cache/weight streaming
  floor — the physically correct decode regime.  `long_500k`: 1.1-3.9x
  (batch-1 keeps ZeRO-3 storage — no replica to amortize replicated weights).
* `select_pool` (dense archs): **1.8-2.5x** dominant-term reduction from
  `dp_over_model` (MoE archs intentionally keep expert parallelism — their
  rows are 1.0x).
* `prefill_32k` rows showing <1x are an **accounting correction, not a
  regression**: baseline probes under-counted blockwise-attention tiles
  (inner `lax.scan` bodies counted once); the optimized sweep unrolls tiles
  in probes (`unroll_blocks`), so the "after" numbers include the full tile
  traffic the "before" numbers missed.  The prefill program itself only
  changed via the global fixes (same or less work).
* `train_4k` rows are ~1.0x on the dominant memory term — consistent with
  the §Perf cell-2 verdicts (the metric is dominated by backward elementwise
  operand counting); train wins landed on FLOPs (1.2x dbrx) and HBM fit.
"""

    path = os.path.join(ROOT, "EXPERIMENTS.md")
    md = open(path).read()
    block = (
        "<!-- ROOFLINE_TABLE -->\n\n### Optimized system (full table)\n\n"
        + table
        + caption
        + "\n### Baseline → optimized (paper-faithful vs beyond-paper)\n\n"
        + compare
        + notes
        + "\n<!-- /ROOFLINE_TABLE -->"
    )
    if "<!-- /ROOFLINE_TABLE -->" in md:
        md = re.sub(
            r"<!-- ROOFLINE_TABLE -->.*?<!-- /ROOFLINE_TABLE -->",
            block.replace("\\", "\\\\"),
            md,
            flags=re.S,
        )
    else:
        md = md.replace("<!-- ROOFLINE_TABLE -->", block)
    with open(path, "w") as f:
        f.write(md)
    print(f"wrote §Roofline: {len(cells)} cells")


if __name__ == "__main__":
    main()
