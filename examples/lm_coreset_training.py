"""End-to-end LM training driver with CRAIG per-epoch coreset selection.

Trains a decoder-only transformer on a synthetic topic-structured token
stream for a few hundred steps, re-selecting a weighted coreset from pooled
unembed-input gradient proxies (paper §3.4) every epoch, with checkpointing
and restart support — the full production loop at laptop scale.

Run:  PYTHONPATH=src python examples/lm_coreset_training.py \
          [--steps 300] [--d-model 256] [--layers 8] [--no-craig]

The default (--d-model 256 --layers 8 --vocab 8192) is a ~12M-param model;
--d-model 768 --layers 12 --vocab 32768 gives ~100M for real hardware.
"""
import argparse
import time

import jax
import numpy as np

from repro.core.craig import CraigConfig
from repro.data.synthetic import TokenStream
from repro.models import ModelConfig, init_params
from repro.optim import adamw, warmup_cosine
from repro.train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--docs", type=int, default=512)
    ap.add_argument("--fraction", type=float, default=0.3)
    ap.add_argument("--no-craig", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="example-lm",
        family="dense",
        n_layers=args.layers,
        d_model=args.d_model,
        n_heads=max(4, args.d_model // 64),
        n_kv_heads=max(2, args.d_model // 128),
        d_ff=args.d_model * 4,
        vocab_size=args.vocab,
        logit_chunk=64,
    )
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params, {args.layers}L d={args.d_model}")

    ds = TokenStream(
        n_docs=args.docs, seq_len=args.seq, vocab_size=args.vocab, n_topics=16
    )
    tcfg = TrainerConfig(
        batch_size=args.batch,
        select_every_epochs=0 if args.no_craig else 1,
        use_craig=not args.no_craig,
        craig=CraigConfig(fraction=args.fraction, per_class=False),
        proxy_pool_batches=args.docs // args.batch,
        checkpoint_dir=args.ckpt,
        checkpoint_every=100,
    )
    trainer = Trainer(
        cfg, tcfg, ds, adamw(warmup_cosine(3e-4, 50, args.steps)),
        lambda: init_params(jax.random.PRNGKey(0), cfg),
    )
    trainer.install_signal_handler()
    if trainer.restore_or_init():
        print(f"restored from checkpoint at step {trainer.step}")

    t0 = time.time()
    log = trainer.run(args.steps)
    dt = time.time() - t0

    steps = [m for m in log if m["event"] == "step"]
    refreshes = [m for m in log if m["event"] == "craig_refresh"]
    first = np.mean([s["loss"] for s in steps[:10]])
    last = np.mean([s["loss"] for s in steps[-10:]])
    print(f"\n{len(steps)} steps in {dt:.1f}s "
          f"({dt/max(len(steps),1)*1e3:.0f} ms/step)")
    print(f"loss: {first:.3f} → {last:.3f}")
    if refreshes:
        sel_t = sum(r["select_time_s"] for r in refreshes)
        print(f"CRAIG: {len(refreshes)} refreshes, coreset "
              f"{refreshes[-1]['coreset_size']}/{args.docs} docs, "
              f"selection overhead {sel_t/dt*100:.1f}% of wall time, "
              f"ε̂={refreshes[-1]['epsilon_hat']:.3f}")
    print(f"distinct data touched: "
          f"{trainer.sampler.active_size}/{args.docs} docs per epoch")


if __name__ == "__main__":
    main()
