"""Quickstart: CRAIG in 60 seconds (paper Fig 1, miniature).

Selects a 10% weighted coreset of a logistic-regression dataset with the
greedy facility-location selector, trains with weighted incremental gradient
descent (paper Eq. 20), and compares against full-data and random-subset
training.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.craig import CraigConfig, CraigSelector
from repro.data.synthetic import make_classification
from repro.optim import ig_run

N, D, LAM, EPOCHS = 1500, 20, 1e-5, 25


def main() -> None:
    x, y = make_classification(N, D, 2, seed=0)
    x = x / np.abs(x).max()
    X, ybin = jnp.asarray(x), jnp.asarray(y * 2.0 - 1.0)

    def grad_one(w, i):
        import jax

        s = jax.nn.sigmoid(-ybin[i] * (X[i] @ w))
        return -s * ybin[i] * X[i] + LAM * w

    def full_loss(w):
        z = -ybin * (X @ w)
        return float(jnp.mean(jnp.log1p(jnp.exp(z))) + 0.5 * LAM * w @ w)

    sched = lambda k: 2.0 / (N * (1 + 0.2 * k))

    # 1) CRAIG selection: per-class facility location over feature proxies
    t0 = time.time()
    cs = CraigSelector(CraigConfig(fraction=0.1, per_class=True)).select(X, y)
    print(f"selected {cs.size}/{N} examples in {time.time()-t0:.2f}s "
          f"(γ sums to {cs.weights.sum():.0f}, ε̂={cs.epsilon_hat:.2f})")

    # 2) train three ways
    runs = {
        "full   ": (np.arange(N), np.ones(N, np.float32)),
        "craig  ": (cs.indices, cs.weights),
        "random ": (
            np.random.RandomState(0).choice(N, cs.size, replace=False),
            np.full(cs.size, N / cs.size, np.float32),
        ),
    }
    print(f"\n{'arm':8s} {'final loss':>11s} {'grad evals':>11s}")
    for name, (idx, w) in runs.items():
        t0 = time.time()
        wgt, _ = ig_run(
            grad_one, jnp.zeros(D), jnp.asarray(idx, jnp.int32),
            jnp.asarray(w), sched, EPOCHS,
        )
        print(
            f"{name:8s} {full_loss(wgt):11.4f} {EPOCHS*len(idx):11d}"
            f"   ({time.time()-t0:.2f}s)"
        )
    print("\nCRAIG ≈ full-data loss at ~10% of the gradient evaluations.")


if __name__ == "__main__":
    main()
