"""Batched serving example: prefill + KV-cache greedy decoding.

Loads (or initializes) a small model, prefills a batch of prompts through
the decode path, and generates continuations with the jitted one-token
serve_step — the same program the decode_32k/long_500k dry-run cells lower
at production scale.

Run:  PYTHONPATH=src python examples/serve_batched.py [--batch 4] [--new 32]
"""
import argparse
import time

import jax
import numpy as np

from repro.models import ModelConfig, init_params
from repro.serve import greedy_generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window attention (0 = global)")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="serve-demo",
        family="hybrid" if args.window else "dense",
        n_layers=4,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=4096,
        window=args.window or None,
        block_pattern=("rglru", "local_attn") if args.window else ("attn",),
        d_rnn=128 if args.window else 0,
        logit_chunk=64,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"model: {cfg.param_count()/1e6:.1f}M params "
          f"({'local window ' + str(args.window) if args.window else 'global attention'})")

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    out = greedy_generate(params, cfg, prompts, max_new=args.new)
    dt = time.time() - t0
    toks = args.batch * (args.prompt_len + args.new)
    print(f"generated {args.batch}×{args.new} tokens in {dt:.2f}s "
          f"({toks/dt:.0f} tok/s incl. prefill+compile)")
    for b in range(min(args.batch, 2)):
        seq = np.asarray(out[b])
        print(f"  req{b}: …{seq[args.prompt_len-4:args.prompt_len].tolist()}"
              f" → {seq[args.prompt_len:args.prompt_len+12].tolist()}…")
    # determinism check
    out2 = greedy_generate(params, cfg, prompts, max_new=args.new)
    assert np.array_equal(np.asarray(out), np.asarray(out2))
    print("deterministic: ✓")


if __name__ == "__main__":
    main()
